//! Blockwise Walsh–Hadamard transform (BWHT, paper §II-A, ref [31]).
//!
//! A monolithic WHT needs a power-of-two dimension; DNN channel counts
//! rarely are (e.g. MobileNetV2 bottlenecks of 96, 144, 960 channels).
//! Zero-padding 960 → 1024 is cheap, but padding 513 → 1024 nearly
//! doubles the tensor. BWHT instead splits the dimension into equal
//! power-of-two blocks and applies an independent WHT per block, bounding
//! worst-case padding and — just as important for the paper's hardware —
//! bounding the *crossbar size* each transform needs.

use super::fwht::fwht_inplace;

/// How a logical dimension `n` maps onto Hadamard blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BwhtLayout {
    /// Logical (un-padded) dimension.
    pub n: usize,
    /// Number of blocks.
    pub blocks: usize,
    /// Power-of-two size of each block.
    pub block_size: usize,
}

impl BwhtLayout {
    /// Choose a layout for dimension `n` with blocks no larger than
    /// `max_block` (a power of two — typically the crossbar size).
    ///
    /// Strategy (following [31]): use ceil(n / max_block) equal blocks,
    /// each the smallest power of two that fits its share. Total padded
    /// length is `blocks * block_size >= n`.
    pub fn new(n: usize, max_block: usize) -> Self {
        assert!(n > 0, "BWHT dimension must be positive");
        assert!(max_block.is_power_of_two(), "max_block must be a power of two");
        let blocks = n.div_ceil(max_block);
        let per_block = n.div_ceil(blocks);
        let block_size = per_block.next_power_of_two();
        BwhtLayout { n, blocks, block_size }
    }

    /// Total padded length (`blocks * block_size`).
    #[inline]
    pub fn padded_len(&self) -> usize {
        self.blocks * self.block_size
    }

    /// Padding overhead as a fraction of `n` (0.0 = no padding).
    pub fn padding_overhead(&self) -> f64 {
        (self.padded_len() as f64 - self.n as f64) / self.n as f64
    }
}

/// Blockwise Walsh–Hadamard transform operator.
///
/// Applies an (unnormalised, natural-order) WHT independently to each
/// block of the padded vector. The transform is parameter-free; the
/// associated learnable state (the soft threshold `T`) lives in the NN
/// layer ([`crate::nn::bwht_layer`]), not here.
#[derive(Debug, Clone)]
pub struct Bwht {
    layout: BwhtLayout,
}

impl Bwht {
    /// Transform for the given block layout.
    pub fn new(layout: BwhtLayout) -> Self {
        Bwht { layout }
    }

    /// Convenience: layout + operator for dimension `n`, blocks ≤ `max_block`.
    pub fn for_dim(n: usize, max_block: usize) -> Self {
        Bwht::new(BwhtLayout::new(n, max_block))
    }

    #[inline]
    /// The block layout.
    pub fn layout(&self) -> BwhtLayout {
        self.layout
    }

    /// Pad a logical vector of length `n` to the block layout.
    pub fn pad(&self, x: &[f32]) -> Vec<f32> {
        let mut p = Vec::new();
        self.pad_into(x, &mut p);
        p
    }

    /// Pad into a caller-owned buffer (allocation-free once warm).
    pub fn pad_into(&self, x: &[f32], out: &mut Vec<f32>) {
        assert_eq!(x.len(), self.layout.n, "input length mismatch");
        out.clear();
        out.resize(self.layout.padded_len(), 0.0);
        out[..x.len()].copy_from_slice(x);
    }

    /// Truncate a padded vector back to the logical length.
    pub fn unpad(&self, p: &[f32]) -> Vec<f32> {
        assert_eq!(p.len(), self.layout.padded_len(), "padded length mismatch");
        p[..self.layout.n].to_vec()
    }

    /// In-place blockwise transform over an already-padded buffer.
    pub fn forward_padded_inplace(&self, p: &mut [f32]) {
        assert_eq!(p.len(), self.layout.padded_len(), "padded length mismatch");
        for chunk in p.chunks_exact_mut(self.layout.block_size) {
            fwht_inplace(chunk);
        }
    }

    /// Forward transform of a logical vector: pad → per-block FWHT.
    /// Output stays in the padded domain (the frequency domain the NN
    /// layer thresholds in).
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut p = self.pad(x);
        self.forward_padded_inplace(&mut p);
        p
    }

    /// In-place blockwise inverse over an already-padded buffer
    /// (blockwise FWHT with the 1/block_size scale). The logical result
    /// is the first `layout.n` values — callers slice, avoiding the
    /// `unpad` copy on the hot path.
    pub fn inverse_padded_inplace(&self, p: &mut [f32]) {
        assert_eq!(p.len(), self.layout.padded_len(), "padded length mismatch");
        let scale = 1.0 / self.layout.block_size as f32;
        for chunk in p.chunks_exact_mut(self.layout.block_size) {
            fwht_inplace(chunk);
            for v in chunk.iter_mut() {
                *v *= scale;
            }
        }
    }

    /// Inverse transform (padded frequency domain → logical vector).
    pub fn inverse(&self, y: &[f32]) -> Vec<f32> {
        let mut p = y.to_vec();
        self.inverse_padded_inplace(&mut p);
        self.unpad(&p)
    }

    /// Additions required per transform (the hardware-relevant cost:
    /// a WHT has no multiplies). `blocks * block_size * log2(block_size)`.
    pub fn add_ops(&self) -> usize {
        let b = self.layout.block_size;
        self.layout.blocks * b * (b.trailing_zeros() as usize)
    }

    /// Equivalent *dense* MAC count if the transform were executed as a
    /// plain matrix multiply (what the paper's Fig 1(d) accounting uses
    /// when comparing against 1×1 convolutions).
    pub fn dense_mac_ops(&self) -> usize {
        let b = self.layout.block_size;
        self.layout.blocks * b * b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wht::matrix::{hadamard, pm1_matvec};

    #[test]
    fn layout_pow2_single_block() {
        let l = BwhtLayout::new(64, 64);
        assert_eq!(l, BwhtLayout { n: 64, blocks: 1, block_size: 64 });
        assert_eq!(l.padding_overhead(), 0.0);
    }

    #[test]
    fn layout_splits_large_dims() {
        // 960 channels with 512-max blocks → 2 blocks of 512.
        let l = BwhtLayout::new(960, 512);
        assert_eq!(l.blocks, 2);
        assert_eq!(l.block_size, 512);
        assert_eq!(l.padded_len(), 1024);
    }

    #[test]
    fn layout_bounds_padding_vs_monolithic() {
        // 513 monolithic would pad to 1024 (~2x). Blockwise stays tight.
        let l = BwhtLayout::new(513, 256);
        assert!(l.padded_len() < 1024, "padded={}", l.padded_len());
        assert!(l.padding_overhead() < 0.5);
    }

    #[test]
    fn forward_matches_blockdiag_dense() {
        let b = Bwht::for_dim(24, 16);
        let l = b.layout();
        assert_eq!(l.blocks, 2);
        assert_eq!(l.block_size, 16);
        let x: Vec<f32> = (0..24).map(|i| (i as f32).sin()).collect();
        let got = b.forward(&x);
        // Dense oracle: block-diagonal Hadamard on the padded vector.
        let h = hadamard(l.block_size);
        let p = b.pad(&x);
        let mut expect = Vec::new();
        for chunk in p.chunks_exact(l.block_size) {
            expect.extend(pm1_matvec(&h, l.block_size, chunk));
        }
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            // Butterfly vs dense association order: float tolerance.
            assert!((g - e).abs() <= 1e-5 * (1.0 + e.abs()), "[{i}] got {g} expect {e}");
        }
    }

    #[test]
    fn forward_inverse_round_trip() {
        for (n, mb) in [(7, 8), (24, 16), (100, 32), (960, 512), (1, 1)] {
            let b = Bwht::for_dim(n, mb);
            let x: Vec<f32> = (0..n).map(|i| ((i * 13 % 7) as f32) - 3.0).collect();
            let y = b.forward(&x);
            let back = b.inverse(&y);
            for (a, e) in back.iter().zip(&x) {
                assert!((a - e).abs() < 1e-4, "n={n} a={a} e={e}");
            }
        }
    }

    #[test]
    fn add_ops_less_than_dense_macs() {
        let b = Bwht::for_dim(960, 512);
        assert!(b.add_ops() < b.dense_mac_ops());
        assert_eq!(b.add_ops(), 2 * 512 * 9);
        assert_eq!(b.dense_mac_ops(), 2 * 512 * 512);
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn pad_rejects_wrong_len() {
        Bwht::for_dim(10, 8).pad(&[0.0; 11]);
    }
}
