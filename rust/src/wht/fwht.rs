//! Fast Walsh–Hadamard transform: O(m log m) butterfly network.
//!
//! This is the digital *oracle* for everything the analog crossbar
//! computes, and the hot loop of the digital-reference inference path.
//! The butterfly structure is also what the L1 Pallas kernel implements
//! (python/compile/kernels/bwht.py) — stage s combines elements at
//! distance 2^s with one add and one subtract, no multiplies.

/// In-place fast Walsh–Hadamard transform, natural (Hadamard) order.
///
/// `x.len()` must be a power of two. Unnormalised: applying twice yields
/// `m * x` (use [`fwht_inverse_inplace`] for the exact inverse).
pub fn fwht_inplace(x: &mut [f32]) {
    let m = x.len();
    assert!(m.is_power_of_two(), "FWHT length must be a power of two, got {m}");
    // PERF: the first two stages have 1- and 2-wide inner loops where
    // loop overhead dominates; specialize them as fixed 2- and 4-point
    // kernels (≈25% faster at large m, see EXPERIMENTS.md §Perf).
    if m >= 2 {
        for pair in x.chunks_exact_mut(2) {
            let (a, b) = (pair[0], pair[1]);
            pair[0] = a + b;
            pair[1] = a - b;
        }
    }
    if m >= 4 {
        for quad in x.chunks_exact_mut(4) {
            let (a, b, c, d) = (quad[0], quad[1], quad[2], quad[3]);
            quad[0] = a + c;
            quad[1] = b + d;
            quad[2] = a - c;
            quad[3] = b - d;
        }
    }
    let mut h = 4;
    while h < m {
        let stride = h * 2;
        let mut base = 0;
        while base < m {
            let (lo, hi) = x[base..base + stride].split_at_mut(h);
            for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                let (va, vb) = (*a, *b);
                *a = va + vb;
                *b = va - vb;
            }
            base += stride;
        }
        h = stride;
    }
}

/// In-place inverse FWHT: `ifwht(fwht(x)) == x` exactly for values
/// representable without rounding (the transform is self-inverse up to
/// the 1/m scale).
pub fn fwht_inverse_inplace(x: &mut [f32]) {
    let m = x.len() as f32;
    fwht_inplace(x);
    for v in x {
        *v /= m;
    }
}

/// Out-of-place inverse FWHT.
pub fn ifwht(x: &[f32]) -> Vec<f32> {
    let mut y = x.to_vec();
    fwht_inverse_inplace(&mut y);
    y
}

/// Gray code of `i`.
#[inline]
fn gray(i: usize) -> usize {
    i ^ (i >> 1)
}

/// Bit-reverse the low `bits` bits of `i`.
#[inline]
fn bit_reverse(i: usize, bits: u32) -> usize {
    if bits == 0 {
        0
    } else {
        i.reverse_bits() >> (usize::BITS - bits)
    }
}

/// Row index in the natural-order Hadamard matrix that has sequency `s`
/// (i.e. the permutation taking Walsh order → Hadamard order).
#[inline]
pub fn walsh_to_hadamard_index(s: usize, bits: u32) -> usize {
    bit_reverse(gray(s), bits)
}

/// In-place FWHT with *sequency* (Walsh) ordered output, matching the
/// dense [`super::matrix::walsh`] matrix.
pub fn fwht_sequency_inplace(x: &mut [f32]) {
    let m = x.len();
    fwht_inplace(x);
    let bits = m.trailing_zeros();
    let snapshot = x.to_vec();
    for s in 0..m {
        x[s] = snapshot[walsh_to_hadamard_index(s, bits)];
    }
}

/// In-place inverse of [`fwht_sequency_inplace`]:
/// `fwht_sequency_inverse(fwht_sequency(x)) == x` (exactly for
/// grid-valued inputs whose butterfly intermediates stay below the f32
/// exact-integer bound — the frontend codec's lossless contract).
///
/// Un-permutes the sequency ordering back to Hadamard order, then
/// applies the self-inverse transform with the `1/m` scale (`m` is a
/// power of two, so the scale multiply is exact).
pub fn fwht_sequency_inverse_inplace(x: &mut [f32]) {
    let m = x.len();
    assert!(m.is_power_of_two(), "FWHT length must be a power of two, got {m}");
    let bits = m.trailing_zeros();
    let snapshot = x.to_vec();
    for s in 0..m {
        x[walsh_to_hadamard_index(s, bits)] = snapshot[s];
    }
    fwht_inplace(x);
    let inv = 1.0 / m as f32;
    for v in x {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wht::matrix::{hadamard, pm1_matvec, walsh};

    fn ramp(m: usize) -> Vec<f32> {
        (0..m).map(|i| (i as f32) - (m as f32) / 3.0).collect()
    }

    fn assert_close(got: &[f32], expect: &[f32], tol: f32, ctx: &str) {
        assert_eq!(got.len(), expect.len(), "{ctx}: length");
        // Error scales with the dynamic range of the whole output vector
        // (cancellation can leave tiny residues where the exact answer is 0).
        let scale = expect.iter().fold(1.0f32, |a, e| a.max(e.abs()));
        for (i, (g, e)) in got.iter().zip(expect).enumerate() {
            assert!((g - e).abs() <= tol * scale, "{ctx}[{i}]: got {g}, expect {e}");
        }
    }

    /// FWHT equals the dense Hadamard product for every size up to 1024.
    #[test]
    fn fwht_matches_dense_hadamard() {
        for k in 0..=10 {
            let m = 1usize << k;
            let h = hadamard(m);
            let x = ramp(m);
            let expect = pm1_matvec(&h, m, &x);
            let mut got = x.clone();
            fwht_inplace(&mut got);
            // Association order differs between butterfly and dense sum;
            // compare with a float tolerance, not bit equality.
            assert_close(&got, &expect, 1e-5, &format!("m={m}"));
        }
    }

    /// Sequency-ordered FWHT equals the dense Walsh product.
    #[test]
    fn fwht_sequency_matches_dense_walsh() {
        for k in 0..=8 {
            let m = 1usize << k;
            let w = walsh(m);
            let x = ramp(m);
            let expect = pm1_matvec(&w, m, &x);
            let mut got = x.clone();
            fwht_sequency_inplace(&mut got);
            assert_close(&got, &expect, 1e-5, &format!("m={m}"));
        }
    }

    /// Self-inverse: ifwht(fwht(x)) == x exactly on integer-valued input.
    #[test]
    fn fwht_round_trip_exact() {
        let x: Vec<f32> = (0..64).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let mut y = x.clone();
        fwht_inplace(&mut y);
        let back = ifwht(&y);
        assert_eq!(back, x);
    }

    /// Parseval: ||fwht(x)||² = m ||x||².
    #[test]
    fn fwht_parseval() {
        let m = 256;
        let x = ramp(m);
        let e_in: f32 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y);
        let e_out: f32 = y.iter().map(|v| v * v).sum();
        let ratio = e_out / (m as f32 * e_in);
        assert!((ratio - 1.0).abs() < 1e-5, "ratio={ratio}");
    }

    /// Sequency round trip: exact on sensor-grid values (the codec's
    /// lossless contract), tight on arbitrary floats.
    #[test]
    fn fwht_sequency_round_trip() {
        for k in 0..=8u32 {
            let m = 1usize << k;
            // Grid values: multiples of 2^-8 in [0, 1] — exact path.
            let x: Vec<f32> = (0..m).map(|i| ((i * 37 % 257) as f32) / 256.0).collect();
            let mut y = x.clone();
            fwht_sequency_inplace(&mut y);
            fwht_sequency_inverse_inplace(&mut y);
            assert_eq!(y, x, "m={m} grid round trip must be bit-exact");
            // Arbitrary floats: tolerance only.
            let x = ramp(m);
            let mut y = x.clone();
            fwht_sequency_inplace(&mut y);
            fwht_sequency_inverse_inplace(&mut y);
            assert_close(&y, &x, 1e-5, &format!("m={m} float"));
        }
    }

    #[test]
    fn walsh_to_hadamard_index_is_permutation() {
        for bits in 0..10u32 {
            let m = 1usize << bits;
            let mut seen = vec![false; m];
            for s in 0..m {
                let i = walsh_to_hadamard_index(s, bits);
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fwht_rejects_non_pow2() {
        fwht_inplace(&mut [1.0, 2.0, 3.0]);
    }

    #[test]
    fn fwht_len1_is_identity() {
        let mut x = [42.0f32];
        fwht_inplace(&mut x);
        assert_eq!(x, [42.0]);
    }
}
