//! Walsh–Hadamard transform substrate.
//!
//! The Walsh–Hadamard transform (WHT) is the frequency transform at the
//! heart of the paper's model-compression scheme (paper §II-A): a unitary
//! (up to scale) transform whose matrix contains only ±1, so a hardware
//! implementation needs no multipliers — additions/subtractions only, which
//! is exactly what the paper's NMOS crossbar (paper §III-A, [`crate::cim`])
//! exploits.
//!
//! Provided here:
//!
//! - [`matrix`] — dense Hadamard matrix `H_k` construction (Sylvester
//!   recursion, paper eq. (2)) and the sequency-ordered *Walsh* matrix
//!   `W_k` (rows sorted by sign-change count).
//! - [`fwht`] — the in-place O(m log m) fast transform (butterfly
//!   network), natural (Hadamard) and sequency (Walsh) ordered variants,
//!   plus the exact inverse.
//! - [`bwht`] — the blockwise Walsh–Hadamard transform (BWHT, paper
//!   §II-A [31]) that handles dimensions that are not a power of two by
//!   splitting the transform into power-of-two blocks, avoiding the
//!   worst-case 2× zero-padding of a monolithic transform.
//! - [`soft_threshold`] — the trainable soft-thresholding activation
//!   `S_T(x) = sign(x)·max(|x|-T, 0)` (paper eq. (3)) that replaces
//!   trainable weights in BWHT layers.

pub mod bwht;
pub mod fwht;
pub mod matrix;

pub use bwht::{Bwht, BwhtLayout};
pub use fwht::{
    fwht_inplace, fwht_inverse_inplace, fwht_sequency_inplace, fwht_sequency_inverse_inplace,
    ifwht,
};
pub use matrix::{hadamard, sequency_of_row, walsh};

/// Soft-thresholding activation `S_T(x)` (paper eq. (3)).
///
/// Shrinks `x` toward zero by `t` and zeroes the dead band `|x| <= t`.
/// `t` is the *trainable* parameter of a BWHT layer; the transform matrix
/// itself is parameter-free.
#[inline]
pub fn soft_threshold(x: f32, t: f32) -> f32 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

/// Vectorised [`soft_threshold`] over a slice, in place.
#[inline]
pub fn soft_threshold_slice(xs: &mut [f32], t: f32) {
    for x in xs {
        *x = soft_threshold(*x, t);
    }
}

/// Smallest power of two `>= n` (used to size Hadamard blocks).
#[inline]
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_dead_band_zeroes() {
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn soft_threshold_shrinks_by_t() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
    }

    #[test]
    fn soft_threshold_zero_t_is_identity() {
        for &x in &[-2.0f32, -0.1, 0.0, 0.1, 7.5] {
            assert_eq!(soft_threshold(x, 0.0), x);
        }
    }

    #[test]
    fn soft_threshold_slice_matches_scalar() {
        let mut v = vec![-2.0f32, -1.0, 0.0, 0.5, 2.5];
        let expect: Vec<f32> = v.iter().map(|&x| soft_threshold(x, 0.75)).collect();
        soft_threshold_slice(&mut v, 0.75);
        assert_eq!(v, expect);
    }

    #[test]
    fn next_pow2_basics() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(960), 1024);
    }
}
