//! Micro-benchmark harness (offline replacement for `criterion`).
//!
//! All bench targets are `harness = false` binaries that call
//! [`bench_fn`] / [`BenchSet`]. The harness does warmup, adaptively picks
//! an iteration count targeting a fixed measurement window, and reports
//! median-of-samples with a simple spread estimate — robust enough for
//! the before/after deltas in EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    /// Inter-quartile-ish spread (p75 - p25) per iteration.
    pub spread: Duration,
    /// Iterations per sample.
    pub iters: u64,
    /// Number of samples.
    pub samples: usize,
}

impl Measurement {
    /// Iterations per second at the median.
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }

    /// Median nanoseconds per iteration (the `BENCH_*.json` unit).
    pub fn ns_per_iter(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} / iter  (± {:>10}, {} iters x {} samples, {:.1}/s)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.spread),
            self.iters,
            self.samples,
            self.per_sec()
        )
    }
}

/// Human-format a duration with ns/µs/ms/s units.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// True when `BENCH_SMOKE` is set (non-empty, not "0"): CI's smoke mode
/// (`scripts/ci.sh --smoke-bench`) shrinks the measurement window and
/// sample count so one bench run finishes in seconds. Smoke numbers are
/// noisy — they prove the bench *runs* and the JSON stays well-formed,
/// never land in `BENCH_hotpath.json`.
pub fn smoke_mode() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Benchmark a closure: warm up, choose iters for ~`window` per sample,
/// take `samples` samples, report the median.
pub fn bench_fn<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    if smoke_mode() {
        return bench_fn_cfg(name, Duration::from_millis(2), 3, &mut f);
    }
    bench_fn_cfg(name, Duration::from_millis(40), 9, &mut f)
}

/// [`bench_fn`] with explicit sample window and count.
pub fn bench_fn_cfg<F: FnMut()>(
    name: &str,
    window: Duration,
    samples: usize,
    f: &mut F,
) -> Measurement {
    // Warmup + calibration: run until we have a time estimate.
    let mut iters: u64 = 1;
    let per_iter_est = loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if dt > Duration::from_millis(5) || iters >= 1 << 24 {
            break dt.as_secs_f64() / iters as f64;
        }
        iters *= 4;
    };
    let iters = ((window.as_secs_f64() / per_iter_est.max(1e-12)).ceil() as u64).max(1);

    let mut per_iter: Vec<f64> = (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        })
        .collect();
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let spread = per_iter[(per_iter.len() * 3) / 4] - per_iter[per_iter.len() / 4];
    Measurement {
        name: name.to_string(),
        median: Duration::from_secs_f64(median),
        spread: Duration::from_secs_f64(spread.max(0.0)),
        iters,
        samples,
    }
}

/// A named group of benchmarks printed as a block (per-figure bench
/// binaries use one `BenchSet` per paper artifact).
pub struct BenchSet {
    title: String,
    results: Vec<Measurement>,
}

impl BenchSet {
    /// Start a titled group (prints the header).
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        BenchSet { title: title.to_string(), results: Vec::new() }
    }

    /// Bench one closure and record its measurement.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        let m = bench_fn(name, f);
        println!("{m}");
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Measurements recorded so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// The group title.
    pub fn title(&self) -> &str {
        &self.title
    }
}

impl BenchSet {
    /// Machine-readable results: the `BENCH_*.json` format every perf PR
    /// commits so the repo accumulates a benchmark trajectory. Schema:
    /// `{"title", "results": [{"name", "ns_per_iter", "spread_ns",
    /// "iters", "samples", "per_sec"}]}`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"title\": {},\n", json_string(&self.title)));
        out.push_str("  \"results\": [\n");
        for (i, m) in self.results.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": {}, \"ns_per_iter\": {:.1}, \"spread_ns\": {:.1}, \
                 \"iters\": {}, \"samples\": {}, \"per_sec\": {:.1}}}{}\n",
                json_string(&m.name),
                m.ns_per_iter(),
                m.spread.as_secs_f64() * 1e9,
                m.iters,
                m.samples,
                m.per_sec(),
                if i + 1 == self.results.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write [`BenchSet::to_json`] to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        let json = self.to_json();
        debug_assert!(json_is_well_formed(&json));
        std::fs::write(path, json)
    }
}

/// Escape a string for JSON.
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Minimal recursive-descent JSON syntax check (no external crates in
/// the offline build). Validates structure only — objects, arrays,
/// strings with escapes, numbers, booleans, null — which is what the
/// `BENCH_*.json` smoke tests assert.
pub fn json_is_well_formed(text: &str) -> bool {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    fn skip_ws(b: &[u8], p: &mut usize) {
        while *p < b.len() && matches!(b[*p], b' ' | b'\t' | b'\n' | b'\r') {
            *p += 1;
        }
    }
    fn value(b: &[u8], p: &mut usize, depth: usize) -> bool {
        if depth > 64 {
            return false;
        }
        skip_ws(b, p);
        match b.get(*p) {
            Some(b'{') => {
                *p += 1;
                skip_ws(b, p);
                if b.get(*p) == Some(&b'}') {
                    *p += 1;
                    return true;
                }
                loop {
                    skip_ws(b, p);
                    if !string(b, p) {
                        return false;
                    }
                    skip_ws(b, p);
                    if b.get(*p) != Some(&b':') {
                        return false;
                    }
                    *p += 1;
                    if !value(b, p, depth + 1) {
                        return false;
                    }
                    skip_ws(b, p);
                    match b.get(*p) {
                        Some(b',') => *p += 1,
                        Some(b'}') => {
                            *p += 1;
                            return true;
                        }
                        _ => return false,
                    }
                }
            }
            Some(b'[') => {
                *p += 1;
                skip_ws(b, p);
                if b.get(*p) == Some(&b']') {
                    *p += 1;
                    return true;
                }
                loop {
                    if !value(b, p, depth + 1) {
                        return false;
                    }
                    skip_ws(b, p);
                    match b.get(*p) {
                        Some(b',') => *p += 1,
                        Some(b']') => {
                            *p += 1;
                            return true;
                        }
                        _ => return false,
                    }
                }
            }
            Some(b'"') => string(b, p),
            Some(b't') => literal(b, p, b"true"),
            Some(b'f') => literal(b, p, b"false"),
            Some(b'n') => literal(b, p, b"null"),
            Some(_) => number(b, p),
            None => false,
        }
    }
    fn literal(b: &[u8], p: &mut usize, lit: &[u8]) -> bool {
        if b[*p..].starts_with(lit) {
            *p += lit.len();
            true
        } else {
            false
        }
    }
    fn string(b: &[u8], p: &mut usize) -> bool {
        if b.get(*p) != Some(&b'"') {
            return false;
        }
        *p += 1;
        while let Some(&c) = b.get(*p) {
            match c {
                b'"' => {
                    *p += 1;
                    return true;
                }
                b'\\' => {
                    *p += 2; // escape + escaped byte (\uXXXX digits are benign)
                }
                _ => *p += 1,
            }
        }
        false
    }
    fn number(b: &[u8], p: &mut usize) -> bool {
        let start = *p;
        if b.get(*p) == Some(&b'-') {
            *p += 1;
        }
        while *p < b.len()
            && matches!(b[*p], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            *p += 1;
        }
        *p > start && b[start..*p].iter().any(|c| c.is_ascii_digit())
    }
    if !value(bytes, &mut pos, 0) {
        return false;
    }
    skip_ws(bytes, &mut pos);
    pos == bytes.len()
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let mut acc = 0u64;
        let m = bench_fn_cfg(
            "noop-ish",
            Duration::from_millis(2),
            3,
            &mut || {
                acc = black_box(acc.wrapping_add(1));
            },
        );
        assert!(m.median.as_nanos() > 0);
        assert!(m.iters >= 1);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12 ns");
        assert!(fmt_dur(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn bench_set_json_is_well_formed() {
        let mut set = BenchSet::new("json \"smoke\"");
        set.run("case a\\b", || {
            black_box(1 + 1);
        });
        set.run("case µs", || {
            black_box(2 + 2);
        });
        let json = set.to_json();
        assert!(json_is_well_formed(&json), "malformed: {json}");
        assert!(json.contains("ns_per_iter"));
    }

    #[test]
    fn json_validator_accepts_and_rejects() {
        for good in [
            "{}",
            "[]",
            "{\"a\": 1, \"b\": [1.5e-3, -2, true, null, \"x\\\"y\"]}",
            "  {\"nested\": {\"deep\": [[[]]]}}  ",
            "3.25",
        ] {
            assert!(json_is_well_formed(good), "rejected valid: {good}");
        }
        for bad in [
            "",
            "{",
            "{\"a\": }",
            "{\"a\": 1,}",
            "[1, 2,]",
            "{\"a\" 1}",
            "{\"a\": 1} trailing",
            "\"unterminated",
            "{'single': 1}",
        ] {
            assert!(!json_is_well_formed(bad), "accepted invalid: {bad}");
        }
    }

    #[test]
    fn sleepy_bench_orders_correctly() {
        // LLVM closed-forms range sums even with opaque bounds; force a
        // per-iteration data dependency so "slow" is genuinely slow.
        let work = |n: u64| {
            let mut acc = 0u64;
            for i in 0..n {
                acc = black_box(acc.wrapping_add(i));
            }
            acc
        };
        let fast = bench_fn_cfg("fast", Duration::from_millis(2), 3, &mut || {
            black_box(work(black_box(8)));
        });
        let slow = bench_fn_cfg("slow", Duration::from_millis(2), 3, &mut || {
            black_box(work(black_box(50_000)));
        });
        assert!(slow.median > fast.median);
    }
}
