//! Micro-benchmark harness (offline replacement for `criterion`).
//!
//! All bench targets are `harness = false` binaries that call
//! [`bench_fn`] / [`BenchSet`]. The harness does warmup, adaptively picks
//! an iteration count targeting a fixed measurement window, and reports
//! median-of-samples with a simple spread estimate — robust enough for
//! the before/after deltas in EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    /// Inter-quartile-ish spread (p75 - p25) per iteration.
    pub spread: Duration,
    /// Iterations per sample.
    pub iters: u64,
    /// Number of samples.
    pub samples: usize,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} / iter  (± {:>10}, {} iters x {} samples, {:.1}/s)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.spread),
            self.iters,
            self.samples,
            self.per_sec()
        )
    }
}

/// Human-format a duration with ns/µs/ms/s units.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark a closure: warm up, choose iters for ~`window` per sample,
/// take `samples` samples, report the median.
pub fn bench_fn<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    bench_fn_cfg(name, Duration::from_millis(40), 9, &mut f)
}

/// [`bench_fn`] with explicit sample window and count.
pub fn bench_fn_cfg<F: FnMut()>(
    name: &str,
    window: Duration,
    samples: usize,
    f: &mut F,
) -> Measurement {
    // Warmup + calibration: run until we have a time estimate.
    let mut iters: u64 = 1;
    let per_iter_est = loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if dt > Duration::from_millis(5) || iters >= 1 << 24 {
            break dt.as_secs_f64() / iters as f64;
        }
        iters *= 4;
    };
    let iters = ((window.as_secs_f64() / per_iter_est.max(1e-12)).ceil() as u64).max(1);

    let mut per_iter: Vec<f64> = (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        })
        .collect();
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let spread = per_iter[(per_iter.len() * 3) / 4] - per_iter[per_iter.len() / 4];
    Measurement {
        name: name.to_string(),
        median: Duration::from_secs_f64(median),
        spread: Duration::from_secs_f64(spread.max(0.0)),
        iters,
        samples,
    }
}

/// A named group of benchmarks printed as a block (per-figure bench
/// binaries use one `BenchSet` per paper artifact).
pub struct BenchSet {
    title: String,
    results: Vec<Measurement>,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        BenchSet { title: title.to_string(), results: Vec::new() }
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        let m = bench_fn(name, f);
        println!("{m}");
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn title(&self) -> &str {
        &self.title
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let mut acc = 0u64;
        let m = bench_fn_cfg(
            "noop-ish",
            Duration::from_millis(2),
            3,
            &mut || {
                acc = black_box(acc.wrapping_add(1));
            },
        );
        assert!(m.median.as_nanos() > 0);
        assert!(m.iters >= 1);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12 ns");
        assert!(fmt_dur(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn sleepy_bench_orders_correctly() {
        // LLVM closed-forms range sums even with opaque bounds; force a
        // per-iteration data dependency so "slow" is genuinely slow.
        let work = |n: u64| {
            let mut acc = 0u64;
            for i in 0..n {
                acc = black_box(acc.wrapping_add(i));
            }
            acc
        };
        let fast = bench_fn_cfg("fast", Duration::from_millis(2), 3, &mut || {
            black_box(work(black_box(8)));
        });
        let slow = bench_fn_cfg("slow", Duration::from_millis(2), 3, &mut || {
            black_box(work(black_box(50_000)));
        });
        assert!(slow.median > fast.median);
    }
}
