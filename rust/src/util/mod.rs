//! In-house utilities.
//!
//! The offline build has exactly two external crates (`xla`, `anyhow`), so
//! this module supplies what a richer dependency tree would normally
//! provide:
//!
//! - [`rng`] — deterministic SplitMix64 PRNG with uniform/normal sampling
//!   (replaces `rand`): every simulation in the library is seedable and
//!   bit-reproducible.
//! - [`stats`] — histograms, mean/std, entropy — used for MAV statistics
//!   (paper Fig 10) and report generation.
//! - [`cli`] — tiny declarative flag parser for the `adcim` binary
//!   (replaces `clap`).
//! - [`bench`] — wall-clock micro-bench harness with warmup and robust
//!   (median) aggregation (replaces `criterion`; all benches are
//!   `harness = false`).
//! - [`executor`] — persistent deterministic worker runtime (replaces
//!   `rayon`-style pools): long-lived workers, channel-fed task
//!   batches, submission-order result merge; shared by the engine's
//!   batch shards and the CiM pool's plane lanes so thread spawn is
//!   paid once per server lifetime, not once per call.
//! - [`prop`] — seeded randomized-property driver (replaces `proptest`):
//!   runs a closure over a few hundred generated cases and reports the
//!   failing seed for replay.
//! - [`loadgen`] — deterministic open/closed-loop load generator for
//!   the serving path (replaces `wrk`-style external harnesses): paced
//!   QPS with bursts or a fixed in-flight window, exact
//!   offered/admitted/shed accounting.
//! - [`telemetry`] — stage-level serving observability (replaces
//!   `metrics`/`tracing`-style crates): bounded log-bucketed latency
//!   histograms, per-request stage spans, executor/pool runtime
//!   counters, and a streaming JSON-lines exporter validated by the
//!   in-house checker.

pub mod bench;
pub mod cli;
pub mod executor;
pub mod loadgen;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod telemetry;

pub use executor::Executor;
pub use rng::Rng;
