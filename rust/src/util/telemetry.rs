//! Stage-level serving telemetry: bounded latency histograms,
//! per-request stage spans, executor/pool runtime counters, and a
//! streaming JSON-lines metrics exporter.
//!
//! Four pieces, all off the bit-exact hot loops:
//!
//! - [`LatencyHistogram`] — a fixed-size log-bucketed (HDR-style)
//!   histogram: values below 256 µs record exactly, larger values land
//!   in 128 linear sub-buckets per power-of-two decade, bounding the
//!   relative quantization error to 1/128 < 1%. Memory is constant
//!   (~7.4k buckets) however long the run, replacing the unbounded
//!   per-completion `Vec` the metrics used to keep. Percentiles use the
//!   same nearest-rank convention as
//!   [`crate::util::stats::percentile_sorted`], so small exact runs
//!   agree bit-for-bit with the old sort-based path.
//! - [`RequestTrace`] — monotonic stage timestamps carried on each
//!   [`crate::coordinator::InferenceRequest`] (admission, batch seal,
//!   engine start/end), turned into a [`StageSample`] at response time:
//!   queue-wait vs batch-wait vs service, telescoping so their sum can
//!   never exceed the end-to-end latency.
//! - [`RuntimeCounters`] — cheap monotone counters from the persistent
//!   [`crate::util::executor::Executor`] (tasks run, per-lane busy-ns,
//!   queue-depth high water) and the collaborative digitization pool
//!   (planes dispatched / fused), sampled at batch granularity by the
//!   serving workers.
//! - [`TelemetrySink`] — a streaming exporter: every
//!   `--metrics-interval-ms` it writes one JSON object per line
//!   (cumulative counters + per-interval deltas) to a file or stderr,
//!   validated by the in-house checker
//!   ([`crate::util::bench::json_is_well_formed`]). Interval rows are
//!   also retained in memory for `adcim loadgen`'s timeline table.
//!
//! Telemetry never feeds scheduling or RNG decisions, so logits are
//! bit-identical with it on or off (pinned by
//! `tests/telemetry_export.rs`).

use std::io::Write;
use std::time::{Duration, Instant};

use crate::coordinator::MetricsSnapshot;
use crate::util::bench::json_string;
use crate::util::executor::ExecutorStats;

/// Values below this bound (µs) occupy one exact bucket each.
const EXACT_LIMIT: u64 = 256;

/// Linear sub-buckets per power-of-two decade above [`EXACT_LIMIT`];
/// bounds the histogram's relative error to `1/SUBBUCKETS`.
const SUBBUCKETS: u64 = 128;

/// Total bucket count: 256 exact + 128 per decade for decades 8..=63.
const NUM_BUCKETS: usize = (EXACT_LIMIT + 56 * SUBBUCKETS) as usize;

/// Bucket index for value `v` (µs).
fn bucket_index(v: u64) -> usize {
    if v < EXACT_LIMIT {
        return v as usize;
    }
    // Decade k = floor(log2 v) in 8..=63; top 8 significant bits pick
    // the linear sub-bucket inside the decade.
    let k = 63 - u64::from(v.leading_zeros());
    let shift = k - 7;
    (EXACT_LIMIT + (k - 8) * SUBBUCKETS + ((v >> shift) - SUBBUCKETS)) as usize
}

/// Smallest value mapping to bucket `idx` — the value the histogram
/// reports for any member of the bucket (exact below [`EXACT_LIMIT`],
/// within 1/128 relative error above).
fn bucket_floor(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < EXACT_LIMIT {
        return idx;
    }
    let b = idx - EXACT_LIMIT;
    let k = b / SUBBUCKETS + 8;
    let off = b % SUBBUCKETS;
    (SUBBUCKETS + off) << (k - 7)
}

/// Fixed-size log-bucketed latency histogram (HDR-style): constant
/// memory for any run length, ≤1% relative quantization error, exact
/// mean/max, and nearest-rank percentiles matching
/// [`crate::util::stats::percentile_sorted`]. See the module docs.
#[derive(Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    max: u64,
    min: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: vec![0; NUM_BUCKETS],
            count: 0,
            sum: 0,
            max: 0,
            min: u64::MAX,
        }
    }
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyHistogram")
            .field("count", &self.count)
            .field("min", &self.min_us())
            .field("max", &self.max)
            .field("mean", &self.mean_us())
            .finish()
    }
}

impl LatencyHistogram {
    /// Fresh, empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value (µs).
    pub fn record(&mut self, us: u64) {
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum += us as u128;
        self.max = self.max.max(us);
        self.min = self.min.min(us);
    }

    /// Recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean of recorded values (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max_us(&self) -> u64 {
        self.max
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Nearest-rank percentile (µs): same rank convention as
    /// [`crate::util::stats::percentile_sorted`], quantized to the
    /// bucket floor (exact below 256 µs, ≤1% relative error above).
    /// Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_floor(idx);
            }
        }
        self.max
    }

    /// Fold another histogram's contents into this one.
    pub fn merge(&mut self, other: &Self) {
        for (b, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    /// Bucket-wise difference against an earlier snapshot of the same
    /// histogram — the interval view the exporter's per-interval p99
    /// is computed from. `min`/`max` of the difference are bucket
    /// floors (quantized), not the exact interval extrema.
    pub fn minus(&self, prev: &Self) -> Self {
        let mut out = Self::new();
        for (idx, (&cur, &old)) in self.buckets.iter().zip(&prev.buckets).enumerate() {
            let d = cur.saturating_sub(old);
            if d > 0 {
                out.buckets[idx] = d;
                out.count += d;
                let floor = bucket_floor(idx);
                out.sum += d as u128 * floor as u128;
                out.max = out.max.max(floor);
                out.min = out.min.min(floor);
            }
        }
        out
    }
}

/// Monotonic stage timestamps carried through the coordinator on each
/// request. Stamped by the serving pipeline (admission → batch seal →
/// engine start/end); all `None` until the request passes the stage.
/// Timestamps never feed scheduling or RNG, so serving output is
/// bit-identical whether or not anyone reads them.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTrace {
    /// Passed admission control (about to enter the ingest queue).
    pub admitted: Option<Instant>,
    /// Sealed into a dispatched batch (one stamp per batch).
    pub sealed: Option<Instant>,
    /// Engine forward started for the request's batch.
    pub engine_start: Option<Instant>,
    /// Engine forward finished for the request's batch.
    pub engine_end: Option<Instant>,
}

/// Saturating microseconds from `a` to `b` (0 if `b` precedes `a`).
fn us_between(a: Instant, b: Instant) -> u64 {
    b.saturating_duration_since(a).as_micros() as u64
}

impl RequestTrace {
    /// Resolve the trace into per-stage durations, given the request's
    /// submit time and the response time. `None` unless every stage
    /// stamp is present (e.g. failure responses synthesized before the
    /// engine ran). The three stages telescope from admission to engine
    /// end, so `queue_wait + batch_wait + service ≤ end_to_end` holds
    /// per sample by construction.
    pub fn stages(&self, submitted: Instant, responded: Instant) -> Option<StageSample> {
        let (admitted, sealed) = (self.admitted?, self.sealed?);
        let (start, end) = (self.engine_start?, self.engine_end?);
        Some(StageSample {
            queue_wait_us: us_between(admitted, sealed),
            batch_wait_us: us_between(sealed, start),
            service_us: us_between(start, end),
            end_to_end_us: us_between(submitted, responded),
        })
    }
}

/// One request's resolved stage durations (µs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageSample {
    /// Admission → batch seal: time spent queued for a batch slot.
    pub queue_wait_us: u64,
    /// Batch seal → engine start: routing + worker-queue wait.
    pub batch_wait_us: u64,
    /// Engine start → engine end: the forward itself.
    pub service_us: u64,
    /// Submit → response: the end-to-end latency the SLO sees.
    pub end_to_end_us: u64,
}

/// Summary of one pipeline stage's latency distribution plus the
/// conversion energy attributed to it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageStats {
    /// Samples that resolved this stage.
    pub count: u64,
    /// Exact mean (µs).
    pub mean_us: f64,
    /// Median (µs, histogram-quantized).
    pub p50_us: u64,
    /// 95th percentile (µs, histogram-quantized).
    pub p95_us: u64,
    /// 99th percentile (µs, histogram-quantized).
    pub p99_us: u64,
    /// Exact worst case (µs).
    pub max_us: u64,
    /// Pool conversion energy attributed to this stage (fJ). All ADC
    /// work happens inside the engine forward, so the full
    /// `ConversionStats` energy lands on the service stage and the
    /// wait stages carry 0.
    pub energy_fj: f64,
}

impl StageStats {
    /// Summarize a stage histogram, attributing `energy_fj` to it.
    pub fn from_histogram(h: &LatencyHistogram, energy_fj: f64) -> Self {
        StageStats {
            count: h.count(),
            mean_us: h.mean_us(),
            p50_us: h.percentile(50.0),
            p95_us: h.percentile(95.0),
            p99_us: h.percentile(99.0),
            max_us: h.max_us(),
            energy_fj,
        }
    }
}

/// The queue-wait / batch-wait / service breakdown reported next to the
/// end-to-end numbers in [`MetricsSnapshot`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageBreakdown {
    /// Admission → batch seal.
    pub queue_wait: StageStats,
    /// Batch seal → engine start.
    pub batch_wait: StageStats,
    /// Engine start → engine end (carries the conversion energy).
    pub service: StageStats,
}

/// Monotone executor/pool runtime counters, sampled per served batch
/// (workers fold the delta since their previous sample into the shared
/// metrics, the same discipline as `ConversionStats`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RuntimeCounters {
    /// Executor tasks executed (shard forwards, pool plane lanes).
    pub exec_tasks: u64,
    /// `Executor::run` batches submitted.
    pub exec_batches: u64,
    /// Deepest the executor's shared job queue has ever been.
    pub exec_queue_high_water: u64,
    /// Execution lanes (spawned workers + the participating caller).
    pub exec_lanes: u64,
    /// Per-lane busy nanoseconds (lane 0 aggregates every submitting
    /// caller's participation; lanes 1.. are the spawned workers).
    pub exec_busy_ns: Vec<u64>,
    /// Planes the digitization pool dispatched (all paths).
    pub planes_dispatched: u64,
    /// Planes that went through the fused (deferred-accounting)
    /// cross-sample submission path.
    pub planes_fused: u64,
}

impl RuntimeCounters {
    /// Lift an executor's counter snapshot (pool counters stay 0).
    pub fn from_executor(s: &ExecutorStats) -> Self {
        RuntimeCounters {
            exec_tasks: s.tasks_run,
            exec_batches: s.batches,
            exec_queue_high_water: s.queue_high_water,
            exec_lanes: s.busy_ns.len() as u64,
            exec_busy_ns: s.busy_ns.clone(),
            planes_dispatched: 0,
            planes_fused: 0,
        }
    }

    /// Delta since an earlier sample of the same counters: monotone
    /// counts subtract (saturating); high-water and lane width keep the
    /// current value (they are levels, not rates).
    pub fn minus(&self, prev: &Self) -> Self {
        let busy = self
            .exec_busy_ns
            .iter()
            .enumerate()
            .map(|(i, &b)| b.saturating_sub(prev.exec_busy_ns.get(i).copied().unwrap_or(0)))
            .collect();
        RuntimeCounters {
            exec_tasks: self.exec_tasks.saturating_sub(prev.exec_tasks),
            exec_batches: self.exec_batches.saturating_sub(prev.exec_batches),
            exec_queue_high_water: self.exec_queue_high_water,
            exec_lanes: self.exec_lanes,
            exec_busy_ns: busy,
            planes_dispatched: self.planes_dispatched.saturating_sub(prev.planes_dispatched),
            planes_fused: self.planes_fused.saturating_sub(prev.planes_fused),
        }
    }

    /// Fold a delta into accumulated totals: monotone counts add,
    /// high-water and lane width take the max (several workers each
    /// own an executor; the snapshot reports the widest/deepest).
    pub fn merge(&mut self, d: &Self) {
        self.exec_tasks += d.exec_tasks;
        self.exec_batches += d.exec_batches;
        self.exec_queue_high_water = self.exec_queue_high_water.max(d.exec_queue_high_water);
        self.exec_lanes = self.exec_lanes.max(d.exec_lanes);
        if self.exec_busy_ns.len() < d.exec_busy_ns.len() {
            self.exec_busy_ns.resize(d.exec_busy_ns.len(), 0);
        }
        for (b, &o) in self.exec_busy_ns.iter_mut().zip(&d.exec_busy_ns) {
            *b += o;
        }
        self.planes_dispatched += d.planes_dispatched;
        self.planes_fused += d.planes_fused;
    }

    /// Total busy nanoseconds across all lanes.
    pub fn busy_total_ns(&self) -> u64 {
        self.exec_busy_ns.iter().sum()
    }

    /// True when every counter is zero (nothing to report).
    pub fn is_zero(&self) -> bool {
        self.exec_tasks == 0
            && self.exec_batches == 0
            && self.planes_dispatched == 0
            && self.planes_fused == 0
            && self.busy_total_ns() == 0
    }
}

/// One exported interval, retained in memory for the loadgen timeline
/// table (the same numbers the JSONL line's `"interval"` object
/// carries).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IntervalRow {
    /// Milliseconds since the sink was created.
    pub t_ms: f64,
    /// Requests offered this interval (admitted + shed + malformed).
    pub offered: u64,
    /// Requests admitted this interval.
    pub admitted: u64,
    /// Requests shed by admission control this interval.
    pub shed: u64,
    /// Wire frames rejected as malformed this interval.
    pub malformed: u64,
    /// Responses delivered this interval.
    pub completed: u64,
    /// Samples served through fused multi-sample forwards this interval.
    pub fused: u64,
    /// p99 end-to-end latency over this interval's completions alone
    /// (µs, from the histogram difference; 0 with no completions).
    pub p99_us: u64,
}

/// Streaming JSON-lines metrics exporter (see the module docs): one
/// self-contained JSON object per flush, cumulative counters plus
/// per-interval deltas, written to any `Write + Send` (file, stderr,
/// an in-memory buffer in tests). Writes are best-effort: a full disk
/// or closed pipe degrades telemetry, never serving.
pub struct TelemetrySink {
    out: Box<dyn Write + Send>,
    interval: Duration,
    label: String,
    started: Instant,
    last_flush: Instant,
    last_t_ms: f64,
    seq: u64,
    prev: Option<MetricsSnapshot>,
    rows: Vec<IntervalRow>,
}

impl std::fmt::Debug for TelemetrySink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TelemetrySink")
            .field("interval", &self.interval)
            .field("label", &self.label)
            .field("seq", &self.seq)
            .finish()
    }
}

/// Format a float as a JSON number (non-finite values, which JSON
/// cannot carry, degrade to 0).
fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "0".to_string()
    }
}

/// Format a `[u64]` slice as a JSON array.
fn jarr(xs: &[u64]) -> String {
    let mut s = String::from("[");
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&x.to_string());
    }
    s.push(']');
    s
}

/// One stage's JSON object for the `"stages"` block.
fn stage_json(s: &StageStats) -> String {
    format!(
        "{{\"count\":{},\"mean_us\":{},\"p50_us\":{},\"p95_us\":{},\"p99_us\":{},\
         \"max_us\":{},\"energy_fj\":{}}}",
        s.count,
        jf(s.mean_us),
        s.p50_us,
        s.p95_us,
        s.p99_us,
        s.max_us,
        jf(s.energy_fj)
    )
}

impl TelemetrySink {
    /// Build a sink flushing every `interval_ms` (clamped to ≥ 1 ms)
    /// to `out`.
    pub fn new(out: Box<dyn Write + Send>, interval_ms: u64) -> Self {
        let now = Instant::now();
        TelemetrySink {
            out,
            interval: Duration::from_millis(interval_ms.max(1)),
            label: String::new(),
            started: now,
            last_flush: now,
            last_t_ms: 0.0,
            seq: 0,
            prev: None,
            rows: Vec::new(),
        }
    }

    /// Attach a free-form run label carried on every line (e.g. the
    /// engine name) — escaped through the same in-house JSON writer
    /// the validator checks.
    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// The configured flush cadence in milliseconds.
    pub fn interval_ms(&self) -> u64 {
        self.interval.as_millis() as u64
    }

    /// True when the flush cadence has elapsed since the last line.
    pub fn due(&self) -> bool {
        self.last_flush.elapsed() >= self.interval
    }

    /// Flush one line if the cadence has elapsed, taking the snapshot
    /// only when actually due (snapshots clone histograms — callers
    /// poll this cheaply from their serving loops). Returns whether a
    /// line was written.
    pub fn maybe_flush_with(&mut self, snap: impl FnOnce() -> MetricsSnapshot) -> bool {
        if !self.due() {
            return false;
        }
        let s = snap();
        self.emit(&s, false);
        true
    }

    /// Write the closing line (`"final":true`) with the run's complete
    /// cumulative counters — summed interval deltas across all lines
    /// reconcile exactly against it.
    pub fn flush_final(&mut self, snap: &MetricsSnapshot) {
        self.emit(snap, true);
    }

    /// Interval rows exported so far (one per line written).
    pub fn rows(&self) -> &[IntervalRow] {
        &self.rows
    }

    /// Lines written so far.
    pub fn lines_written(&self) -> u64 {
        self.seq
    }

    fn emit(&mut self, snap: &MetricsSnapshot, is_final: bool) {
        let now = Instant::now();
        // Strictly increasing export clock even for back-to-back lines.
        let mut t_ms = now.duration_since(self.started).as_secs_f64() * 1e3;
        if t_ms <= self.last_t_ms {
            t_ms = self.last_t_ms + 0.001;
        }

        let admitted: u64 = snap.qos_admitted.iter().sum();
        let shed: u64 = snap.qos_shed.iter().sum();
        let malformed = snap.rejected_malformed;
        let offered = admitted + shed + malformed;

        // Interval deltas against the previously exported snapshot.
        let (p_adm, p_shed, p_mal, p_done, p_err, p_fused) = match &self.prev {
            Some(p) => (
                p.qos_admitted.iter().sum::<u64>(),
                p.qos_shed.iter().sum::<u64>(),
                p.rejected_malformed,
                p.completed,
                p.errors,
                p.samples_fused,
            ),
            None => (0, 0, 0, 0, 0, 0),
        };
        let d_adm = admitted.saturating_sub(p_adm);
        let d_shed = shed.saturating_sub(p_shed);
        let d_mal = malformed.saturating_sub(p_mal);
        let d_done = snap.completed.saturating_sub(p_done);
        let d_err = snap.errors.saturating_sub(p_err);
        let d_fused = snap.samples_fused.saturating_sub(p_fused);
        let d_p99 = match &self.prev {
            Some(p) => snap.latency_hist.minus(&p.latency_hist).percentile(99.0),
            None => snap.latency_hist.percentile(99.0),
        };

        let line = format!(
            "{{\"schema\":\"adcim.telemetry.v1\",\"seq\":{},\"final\":{},\"label\":{},\
             \"t_ms\":{},\"interval_ms\":{},\
             \"completed\":{},\"errors\":{},\"degraded\":{},\"panics\":{},\
             \"rejected_queue\":{},\"rejected_malformed\":{},\
             \"admitted\":{},\"shed\":{},\"offered\":{},\
             \"mean_us\":{},\"p50_us\":{},\"p95_us\":{},\"p99_us\":{},\"max_us\":{},\
             \"throughput_per_s\":{},\"mean_batch\":{},\"fused\":{},\
             \"conversions\":{},\"gated\":{},\"adc_energy_fj\":{},\
             \"qos_admitted\":{},\"qos_shed\":{},\
             \"stages\":{{\"queue_wait\":{},\"batch_wait\":{},\"service\":{}}},\
             \"exec\":{{\"tasks\":{},\"batches\":{},\"queue_high_water\":{},\"lanes\":{},\
             \"busy_ns\":{}}},\
             \"pool\":{{\"planes_dispatched\":{},\"planes_fused\":{}}},\
             \"faults\":{{\"injected\":{},\"stuck_cells\":{},\"drifting\":{},\"dead\":{},\
             \"arrays_down\":{},\"probes_run\":{},\"probes_failed\":{},\"quarantined\":{},\
             \"degraded_planes\":{},\"rerouted\":{},\"mav_oob\":{}}},\
             \"shutdown_forced\":{},\
             \"interval\":{{\"offered\":{},\"admitted\":{},\"shed\":{},\"malformed\":{},\
             \"completed\":{},\"errors\":{},\"fused\":{},\"p99_us\":{}}}}}",
            self.seq,
            is_final,
            json_string(&self.label),
            jf(t_ms),
            self.interval.as_millis(),
            snap.completed,
            snap.errors,
            snap.degraded,
            snap.panics_isolated,
            snap.rejected_queue_full,
            snap.rejected_malformed,
            admitted,
            shed,
            offered,
            jf(snap.mean_latency_us),
            jf(snap.p50_latency_us),
            jf(snap.p95_latency_us),
            jf(snap.p99_latency_us),
            jf(snap.max_latency_us),
            jf(snap.throughput_per_s),
            jf(snap.mean_batch),
            snap.samples_fused,
            snap.conversions,
            snap.conversions_gated,
            jf(snap.adc_energy_fj),
            jarr(&snap.qos_admitted),
            jarr(&snap.qos_shed),
            stage_json(&snap.stages.queue_wait),
            stage_json(&snap.stages.batch_wait),
            stage_json(&snap.stages.service),
            snap.runtime.exec_tasks,
            snap.runtime.exec_batches,
            snap.runtime.exec_queue_high_water,
            snap.runtime.exec_lanes,
            jarr(&snap.runtime.exec_busy_ns),
            snap.runtime.planes_dispatched,
            snap.runtime.planes_fused,
            snap.faults.faults_injected,
            snap.faults.stuck_cells,
            snap.faults.converters_drifting,
            snap.faults.converters_dead,
            snap.faults.arrays_down,
            snap.faults.probes_run,
            snap.faults.probes_failed,
            snap.faults.quarantined,
            snap.faults.degraded_planes,
            snap.faults.conversions_rerouted,
            snap.faults.mav_out_of_bounds,
            snap.shutdown_forced,
            d_adm + d_shed + d_mal,
            d_adm,
            d_shed,
            d_mal,
            d_done,
            d_err,
            d_fused,
            d_p99,
        );
        let _ = writeln!(self.out, "{line}");
        let _ = self.out.flush();

        self.rows.push(IntervalRow {
            t_ms,
            offered: d_adm + d_shed + d_mal,
            admitted: d_adm,
            shed: d_shed,
            malformed: d_mal,
            completed: d_done,
            fused: d_fused,
            p99_us: d_p99,
        });
        self.prev = Some(snap.clone());
        self.seq += 1;
        self.last_flush = now;
        self.last_t_ms = t_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::util::bench::json_is_well_formed;
    use crate::util::stats::percentile_sorted;
    use crate::util::Rng;
    use std::sync::{Arc, Mutex};

    #[test]
    fn histogram_is_exact_below_256us() {
        let mut h = LatencyHistogram::new();
        let vals = [0u64, 1, 7, 100, 200, 255];
        for &v in &vals {
            h.record(v);
        }
        let sorted: Vec<f64> = vals.iter().map(|&v| v as f64).collect();
        for p in [0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p) as f64, percentile_sorted(&sorted, p), "p{p}");
        }
        assert_eq!(h.max_us(), 255);
        assert_eq!(h.min_us(), 0);
        assert!((h.mean_us() - sorted.iter().sum::<f64>() / 6.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentile_parity_within_one_percent() {
        // S1 parity gate: against the exact sort-based percentile on a
        // seeded spread spanning every decade the serving path sees.
        let mut rng = Rng::new(0x7e1e);
        let mut h = LatencyHistogram::new();
        let mut vals = Vec::new();
        for _ in 0..4000 {
            // Log-uniform over [1, ~2^30) µs.
            let exp = rng.next_u64() % 30;
            let v = (1u64 << exp) + rng.next_u64() % (1u64 << exp);
            h.record(v);
            vals.push(v as f64);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [50.0, 90.0, 95.0, 99.0, 99.9] {
            let exact = percentile_sorted(&vals, p);
            let approx = h.percentile(p) as f64;
            assert!(approx <= exact, "p{p}: floor {approx} above exact {exact}");
            let rel = (exact - approx) / exact.max(1.0);
            assert!(rel <= 1.0 / 128.0 + 1e-12, "p{p}: rel err {rel}");
        }
    }

    #[test]
    fn histogram_merge_and_minus_roundtrip() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for v in [10u64, 20, 5000] {
            a.record(v);
        }
        for v in [30u64, 70_000] {
            b.record(v);
        }
        let mut both = a.clone();
        both.merge(&b);
        assert_eq!(both.count(), 5);
        let diff = both.minus(&a);
        assert_eq!(diff.count(), b.count());
        assert_eq!(diff.percentile(50.0), b.percentile(50.0));
        // Interval of an unchanged histogram is empty.
        assert!(both.minus(&both).is_empty());
    }

    #[test]
    fn bucket_index_floor_are_consistent() {
        // floor(index(v)) ≤ v with ≤1/128 relative error, all decades.
        let mut rng = Rng::new(0xb0b);
        for _ in 0..20_000 {
            let exp = rng.next_u64() % 63;
            let v = (1u64 << exp) + rng.next_u64() % (1u64 << exp).max(1);
            let f = bucket_floor(bucket_index(v));
            assert!(f <= v, "floor {f} above {v}");
            assert!(v - f <= v / 128, "floor {f} too far below {v}");
        }
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn trace_stages_telescope_under_end_to_end() {
        let t0 = Instant::now();
        let step = Duration::from_micros(100);
        let trace = RequestTrace {
            admitted: Some(t0 + step),
            sealed: Some(t0 + 2 * step),
            engine_start: Some(t0 + 3 * step),
            engine_end: Some(t0 + 5 * step),
        };
        let s = trace.stages(t0, t0 + 6 * step).expect("all stamps present");
        assert_eq!(s.queue_wait_us, 100);
        assert_eq!(s.batch_wait_us, 100);
        assert_eq!(s.service_us, 200);
        assert_eq!(s.end_to_end_us, 600);
        assert!(s.queue_wait_us + s.batch_wait_us + s.service_us <= s.end_to_end_us);
        // Missing stamps (degraded responses) resolve to None.
        assert!(RequestTrace::default().stages(t0, t0 + step).is_none());
    }

    #[test]
    fn runtime_counters_minus_merge() {
        let mut cur = RuntimeCounters {
            exec_tasks: 10,
            exec_batches: 4,
            exec_queue_high_water: 7,
            exec_lanes: 2,
            exec_busy_ns: vec![500, 300],
            planes_dispatched: 20,
            planes_fused: 8,
        };
        let prev = RuntimeCounters {
            exec_tasks: 6,
            exec_batches: 2,
            exec_queue_high_water: 5,
            exec_lanes: 2,
            exec_busy_ns: vec![200, 100],
            planes_dispatched: 12,
            planes_fused: 8,
        };
        let d = cur.minus(&prev);
        assert_eq!(d.exec_tasks, 4);
        assert_eq!(d.exec_busy_ns, vec![300, 200]);
        assert_eq!(d.exec_queue_high_water, 7, "high water is a level");
        assert_eq!(d.planes_dispatched, 8);
        assert_eq!(d.planes_fused, 0);
        let mut tot = RuntimeCounters::default();
        tot.merge(&d);
        tot.merge(&d);
        assert_eq!(tot.exec_tasks, 8);
        assert_eq!(tot.busy_total_ns(), 1000);
        assert_eq!(tot.exec_queue_high_water, 7);
        assert!(!tot.is_zero());
        cur.merge(&RuntimeCounters::default());
        assert_eq!(cur.exec_tasks, 10);
    }

    /// `Write` handle into a shared buffer, for asserting on emitted
    /// lines.
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn sink_emits_validator_clean_reconciling_lines() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut sink =
            TelemetrySink::new(Box::new(SharedBuf(buf.clone())), 1).with_label("unit \"test\"");
        let m = Metrics::new();
        m.record_qos(3, true);
        m.record_qos(3, true);
        m.record_qos(0, false);
        m.record_batch(2);
        m.record_completion(120);
        m.record_completion(300);
        sink.emit(&m.snapshot(), false);
        m.record_qos(2, true);
        m.record_completion(90);
        sink.flush_final(&m.snapshot());

        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in &lines {
            assert!(json_is_well_formed(l), "bad JSON line: {l}");
        }
        assert!(lines[0].contains("\"final\":false"));
        assert!(lines[1].contains("\"final\":true"));
        // Fault-free runs still carry the (all-zero) faults block, so
        // downstream parsers see a stable schema.
        assert!(lines[0].contains("\"faults\":{\"injected\":0"), "{}", lines[0]);
        assert!(lines[0].contains("\"shutdown_forced\":0"), "{}", lines[0]);
        assert!(lines[1].contains("\"label\":\"unit \\\"test\\\"\""));
        // Interval deltas reconcile: rows sum to final cumulative.
        let rows = sink.rows();
        assert_eq!(rows.len(), 2);
        assert!(rows[1].t_ms > rows[0].t_ms, "strictly time-ordered");
        assert_eq!(rows.iter().map(|r| r.offered).sum::<u64>(), 4);
        assert_eq!(rows.iter().map(|r| r.admitted).sum::<u64>(), 3);
        assert_eq!(rows.iter().map(|r| r.shed).sum::<u64>(), 1);
        assert_eq!(rows.iter().map(|r| r.completed).sum::<u64>(), 3);
        for r in rows {
            assert_eq!(r.offered, r.admitted + r.shed + r.malformed);
        }
    }

    #[test]
    fn sink_flushes_on_cadence_only() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let mut sink = TelemetrySink::new(Box::new(SharedBuf(buf.clone())), 1_000);
        let m = Metrics::new();
        // Immediately after construction the cadence has not elapsed:
        // the closure must not even be evaluated.
        assert!(!sink.maybe_flush_with(|| unreachable!("sink not due")));
        assert_eq!(sink.lines_written(), 0);
        // A final flush always writes.
        sink.flush_final(&m.snapshot());
        assert_eq!(sink.lines_written(), 1);
    }
}
