//! Minimal declarative CLI flag parser (offline replacement for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments. The `adcim` binary defines subcommands on top of this.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key→value options and positionals, in order.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: Vec<String>,
    opts: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse a raw argument list (excluding argv[0]).
    ///
    /// A `--key` followed by a token that does not start with `--` is
    /// treated as `--key value` if `takes_value(key)` returns true,
    /// otherwise as a bare flag. Pass the set of value-taking keys.
    pub fn parse<I, S>(raw: I, value_keys: &[&str]) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let toks: Vec<String> = raw.into_iter().map(Into::into).collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if value_keys.contains(&body)
                    && i + 1 < toks.len()
                    && !toks[i + 1].starts_with("--")
                {
                    out.opts.insert(body.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// True when `--name` was passed bare.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--key value`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// Value of `--key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parsed value of `--key` (None on absence or parse failure).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// Parsed value of `--key`, or `default`.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get_parse(key).unwrap_or(default)
    }

    /// Non-flag arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flags_opts_positionals() {
        let a = Args::parse(
            ["serve", "--port", "8080", "--verbose", "--mode=hybrid", "extra"],
            &["port"],
        );
        assert_eq!(a.positional(), &["serve".to_string(), "extra".to_string()]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("mode"), Some("hybrid"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn value_key_without_value_is_flag() {
        let a = Args::parse(["--port"], &["port"]);
        assert!(a.flag("port"));
        assert_eq!(a.get("port"), None);
    }

    #[test]
    fn non_value_key_does_not_consume_next() {
        let a = Args::parse(["--verbose", "cmd"], &["port"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["cmd".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(["--n=32"], &[]);
        assert_eq!(a.get_parse::<usize>("n"), Some(32));
        assert_eq!(a.get_parse_or::<usize>("m", 7), 7);
    }
}
