//! Seeded randomized-property driver (offline replacement for `proptest`).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` generated
//! inputs. On failure it panics with the case's replay seed so the exact
//! input can be regenerated with `replay(seed, f)`.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: u64 = 256;

/// Run `f` over `cases` seeded RNGs; panic with a replayable seed message
/// if any case returns an `Err`.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is derived from the property name so distinct properties
    // explore distinct streams but remain deterministic run-to-run.
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut f: F) -> Result<(), String>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    f(&mut Rng::new(seed))
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always-true", 32, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 4, |_rng| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces_case_stream() {
        let mut first = None;
        let _ = replay(1234, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second = None;
        let _ = replay(1234, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn prop_assert_macro_shortcircuits() {
        let body = |rng: &mut crate::util::Rng| -> Result<(), String> {
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        };
        check("macro-smoke", 16, body);
    }
}
