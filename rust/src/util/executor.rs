//! Persistent deterministic worker runtime.
//!
//! The PR-1/PR-3 parallel paths (`AnalogEngine::infer_sharded` batch
//! shards, `CimArrayPool::process_planes` coupling-group lanes) each
//! opened a fresh `std::thread::scope` per call — thread spawn/join on
//! every served batch and every pooled plane submission. [`Executor`]
//! moves that cost to construction time: a fixed set of long-lived
//! workers is fed task batches over a shared channel, and the spawn is
//! paid once per server lifetime instead of once per request.
//!
//! Determinism contract (the same one every parallel path in this repo
//! already obeys): [`Executor::run`] returns results **in submission
//! order**, whatever worker ran what and in whatever order tasks
//! finished. Callers that need bit-identical float accumulation merge
//! the ordered results themselves — exactly like the PR-1 shard merge
//! and the PR-3 per-plane stat merge. The executor adds no ordering
//! hazards of its own because it never aggregates; it only transports.
//!
//! Scheduling shape:
//!
//! - `Executor::new(lanes)` spawns `lanes − 1` workers; the **caller
//!   participates** in executing its own batch (and anything else in
//!   the queue) while it waits. An executor with `lanes == 1` therefore
//!   has zero worker threads and `run` degenerates to an inline
//!   sequential loop — the sequential path stays spawn-free *and*
//!   allocation-cheap.
//! - Caller participation also makes nested submission safe: a batch
//!   shard running on a worker can submit pool plane lanes to the
//!   *same* executor without deadlock, because every `run` caller
//!   drains queue work itself until its batch completes. This is what
//!   lets one shared runtime serve both `engine_threads` and
//!   `pool_threads` instead of multiplying them.
//! - A panicking task does not poison the runtime: the panic is caught
//!   on the executing thread, the batch still completes, and the
//!   payload is re-thrown from the submitting `run` call (the same
//!   observable behaviour as the old `scope.join().expect(...)`).
//!
//! Dropping the executor shuts the workers down and joins them.
//!
//! Safety: `run` erases task lifetimes to move borrows onto the
//! long-lived workers (the classic scoped-pool trick). The erasure is
//! sound because `run` does not return until every task in the batch
//! has finished executing — the borrows it smuggled out are dead before
//! the caller's frame can be.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A lifetime-erased queued task.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the submitting threads and the workers.
struct Queue {
    state: Mutex<QueueState>,
    /// Signalled when jobs arrive or shutdown is requested.
    work: Condvar,
    /// Cheap monotone counters (relaxed atomics, bumped per task/batch
    /// — never per row, and never read by scheduling decisions, so
    /// they cannot perturb determinism). Surfaced by
    /// [`Executor::stats`] for the telemetry layer.
    counters: QueueCounters,
}

/// The executor's telemetry counters (see [`ExecutorStats`]).
struct QueueCounters {
    tasks_run: AtomicU64,
    batches: AtomicU64,
    queue_high_water: AtomicU64,
    /// Busy nanoseconds per lane: index 0 aggregates every submitting
    /// caller's participation, indices 1.. are the spawned workers.
    busy_ns: Vec<AtomicU64>,
}

/// Execute one queued job on `lane`, timing it into the counters.
fn run_job(queue: &Queue, lane: usize, job: Job) {
    let t0 = Instant::now();
    job();
    let c = &queue.counters;
    c.busy_ns[lane].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    c.tasks_run.fetch_add(1, Ordering::Relaxed);
}

/// Snapshot of an [`Executor`]'s cumulative runtime counters: how much
/// work the lanes actually did and how deep the shared queue got —
/// the oversubscription / utilization signal the telemetry snapshots
/// carry. All counters are monotone since construction.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    /// Tasks executed across all lanes.
    pub tasks_run: u64,
    /// [`Executor::run`] batches submitted.
    pub batches: u64,
    /// Deepest the shared job queue has ever been (at enqueue time).
    pub queue_high_water: u64,
    /// Busy nanoseconds per lane: index 0 aggregates every submitting
    /// caller's participation, indices 1.. are the spawned workers.
    pub busy_ns: Vec<u64>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Completion tracking for one `run` batch.
struct BatchState {
    remaining: Mutex<usize>,
    done: Condvar,
    /// First panic payload observed in this batch, re-thrown by `run`.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Resolve a lane/thread-count knob: `0` = auto-detect from available
/// parallelism. The one home of the "0 = auto" policy every thread
/// knob in the crate shares (engine sharding, pool fan-out, executor
/// sizing).
pub fn resolve_lanes(lanes: usize) -> usize {
    match lanes {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// A persistent pool of worker threads with submission-order result
/// delivery (see module docs). Cheaply shared via `Arc` between the
/// engine's batch shards and the pool's plane lanes.
pub struct Executor {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    lanes: usize,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor").field("lanes", &self.lanes).finish()
    }
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // Our jobs never panic while holding these locks (task panics are
    // caught before the bookkeeping section), but stay robust anyway.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Executor {
    /// Build a runtime with `lanes` total execution lanes: `lanes − 1`
    /// spawned workers plus the submitting caller. `0` auto-detects
    /// from available parallelism.
    pub fn new(lanes: usize) -> Self {
        let lanes = resolve_lanes(lanes);
        let queue = Arc::new(Queue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
            counters: QueueCounters {
                tasks_run: AtomicU64::new(0),
                batches: AtomicU64::new(0),
                queue_high_water: AtomicU64::new(0),
                busy_ns: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            },
        });
        let workers = (1..lanes)
            .map(|lane| {
                let queue = queue.clone();
                std::thread::spawn(move || worker_loop(&queue, lane))
            })
            .collect();
        Executor { queue, workers, lanes }
    }

    /// Total execution lanes (spawned workers + the participating
    /// caller).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Snapshot the cumulative runtime counters (cheap relaxed loads;
    /// safe to call from any thread at any time).
    pub fn stats(&self) -> ExecutorStats {
        let c = &self.queue.counters;
        ExecutorStats {
            tasks_run: c.tasks_run.load(Ordering::Relaxed),
            batches: c.batches.load(Ordering::Relaxed),
            queue_high_water: c.queue_high_water.load(Ordering::Relaxed),
            busy_ns: c.busy_ns.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Execute `tasks`, returning their results **in submission order**.
    /// Blocks until every task has completed; the calling thread
    /// executes queued work itself while it waits (so nested `run`
    /// calls from inside a task cannot deadlock, and `lanes == 1` runs
    /// everything inline). Re-throws the first task panic after the
    /// batch drains.
    pub fn run<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let batch =
            BatchState { remaining: Mutex::new(n), done: Condvar::new(), panic: Mutex::new(None) };
        {
            let batch_ref = &batch;
            let mut jobs: Vec<Job> = Vec::with_capacity(n);
            for (slot, task) in slots.iter_mut().zip(tasks) {
                let job = move || {
                    match catch_unwind(AssertUnwindSafe(task)) {
                        Ok(v) => *slot = Some(v),
                        Err(payload) => {
                            let mut first = lock(&batch_ref.panic);
                            if first.is_none() {
                                *first = Some(payload);
                            }
                        }
                    }
                    let mut remaining = lock(&batch_ref.remaining);
                    *remaining -= 1;
                    if *remaining == 0 {
                        batch_ref.done.notify_all();
                    }
                };
                // SAFETY: the job borrows `slots` and `batch`, which
                // outlive this block — `run` blocks below until
                // `remaining == 0`, i.e. until every job (including
                // this one) has finished executing, so the erased
                // borrows never dangle.
                jobs.push(unsafe { erase_job(Box::new(job)) });
            }
            {
                let mut q = lock(&self.queue.state);
                q.jobs.extend(jobs);
                // High-water mark while still under the queue lock, so
                // the depth reading is exact, not racy.
                self.queue
                    .counters
                    .queue_high_water
                    .fetch_max(q.jobs.len() as u64, Ordering::Relaxed);
            }
            self.queue.counters.batches.fetch_add(1, Ordering::Relaxed);
            self.queue.work.notify_all();

            // Caller participation: drain queue work (ours or anyone
            // else's) until this batch completes.
            loop {
                {
                    let remaining = lock(&batch.remaining);
                    if *remaining == 0 {
                        break;
                    }
                }
                let job = lock(&self.queue.state).jobs.pop_front();
                match job {
                    // Caller participation accounts its busy time on
                    // lane 0 (shared by every submitting thread).
                    Some(job) => run_job(&self.queue, 0, job),
                    None => {
                        let remaining = lock(&batch.remaining);
                        if *remaining == 0 {
                            break;
                        }
                        // Short timeout: a nested batch may refill the
                        // queue without signalling `done`; wake up and
                        // help rather than idling until our own batch
                        // finishes.
                        let _ = self.batch_wait(&batch, remaining, Duration::from_micros(200));
                    }
                }
            }
        }
        if let Some(payload) = lock(&batch.panic).take() {
            resume_unwind(payload);
        }
        slots
            .into_iter()
            .map(|s| s.expect("executor batch drained with an unfilled result slot"))
            .collect()
    }

    fn batch_wait<'g>(
        &self,
        batch: &'g BatchState,
        guard: std::sync::MutexGuard<'g, usize>,
        timeout: Duration,
    ) -> std::sync::MutexGuard<'g, usize> {
        let (guard, _) =
            batch.done.wait_timeout(guard, timeout).unwrap_or_else(PoisonError::into_inner);
        guard
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut q = lock(&self.queue.state);
            q.shutdown = true;
        }
        self.queue.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// SAFETY: caller must guarantee the job finishes executing before any
/// borrow it captures expires (see [`Executor::run`]).
unsafe fn erase_job<'a>(job: Box<dyn FnOnce() + Send + 'a>) -> Job {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send + 'static>>(job)
}

fn worker_loop(queue: &Queue, lane: usize) {
    loop {
        let job = {
            let mut state = lock(&queue.state);
            loop {
                if let Some(job) = state.jobs.pop_front() {
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = queue.work.wait(state).unwrap_or_else(PoisonError::into_inner);
            }
        };
        run_job(queue, lane, job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_come_back_in_submission_order() {
        let exec = Executor::new(4);
        // Tasks finish out of order (later tasks sleep less); results
        // must still land by submission index.
        let tasks: Vec<_> = (0..16u64)
            .map(|i| {
                move || {
                    std::thread::sleep(Duration::from_micros((16 - i) * 50));
                    i * i
                }
            })
            .collect();
        let got = exec.run(tasks);
        assert_eq!(got, (0..16u64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_lane_runs_inline_and_ordered() {
        let exec = Executor::new(1);
        assert_eq!(exec.lanes(), 1);
        // With one lane (zero workers) every task runs on the caller,
        // in submission order: the execution stamps are sequential.
        let seq = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..5usize)
            .map(|i| {
                let seq = &seq;
                move || (i, seq.fetch_add(1, Ordering::Relaxed))
            })
            .collect();
        let got = exec.run(tasks);
        assert_eq!(got, (0..5).map(|i| (i, i)).collect::<Vec<_>>());
    }

    #[test]
    fn reuse_across_batches_accumulates() {
        let exec = Executor::new(3);
        let counter = AtomicUsize::new(0);
        for round in 0..10usize {
            let tasks: Vec<_> = (0..8)
                .map(|_| {
                    let counter = &counter;
                    move || counter.fetch_add(1, Ordering::Relaxed)
                })
                .collect();
            let got = exec.run(tasks);
            assert_eq!(got.len(), 8, "round {round}");
        }
        assert_eq!(counter.load(Ordering::Relaxed), 80);
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        let exec = Arc::new(Executor::new(2));
        // Outer tasks each submit an inner batch to the same executor;
        // with 2 lanes this would deadlock without caller participation.
        let outer: Vec<_> = (0..4u64)
            .map(|i| {
                let exec = exec.clone();
                move || {
                    let inner: Vec<_> = (0..3u64).map(|j| move || i * 10 + j).collect();
                    exec.run(inner).iter().sum::<u64>()
                }
            })
            .collect();
        let got = exec.run(outer);
        assert_eq!(got, vec![3, 33, 63, 93]);
    }

    #[test]
    fn task_panic_propagates_after_batch_drains() {
        let exec = Executor::new(2);
        let completed = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..6usize)
                .map(|i| {
                    let completed = &completed;
                    Box::new(move || {
                        if i == 2 {
                            panic!("task 2 exploded");
                        }
                        completed.fetch_add(1, Ordering::Relaxed);
                        i
                    }) as Box<dyn FnOnce() -> usize + Send>
                })
                .collect();
            exec.run(tasks)
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // All non-panicking tasks still ran (batch drained, runtime not
        // poisoned)...
        assert_eq!(completed.load(Ordering::Relaxed), 5);
        // ...and the executor is still usable afterwards.
        let got = exec.run((0..4usize).map(|i| move || i + 1).collect::<Vec<_>>());
        assert_eq!(got, vec![1, 2, 3, 4]);
    }

    #[test]
    fn drop_joins_workers() {
        let exec = Executor::new(4);
        let _ = exec.run((0..8usize).map(|i| move || i).collect::<Vec<_>>());
        drop(exec); // must not hang
    }

    #[test]
    fn stats_count_tasks_batches_and_busy_time() {
        let exec = Executor::new(2);
        assert_eq!(exec.stats(), ExecutorStats { busy_ns: vec![0, 0], ..Default::default() });
        for _ in 0..3 {
            let tasks: Vec<_> = (0..4u64)
                .map(|i| move || std::thread::sleep(Duration::from_micros(50 + i)))
                .collect();
            exec.run(tasks);
        }
        let s = exec.stats();
        assert_eq!(s.tasks_run, 12);
        assert_eq!(s.batches, 3);
        assert!(s.queue_high_water >= 1 && s.queue_high_water <= 4, "{s:?}");
        assert_eq!(s.busy_ns.len(), 2, "one slot per lane");
        // Every task slept ≥50µs somewhere; total busy time must show it.
        assert!(s.busy_ns.iter().sum::<u64>() >= 12 * 50_000, "{s:?}");
    }

    #[test]
    fn auto_lanes_detects_at_least_one() {
        let exec = Executor::new(0);
        assert!(exec.lanes() >= 1);
        let got = exec.run(vec![|| 7usize]);
        assert_eq!(got, vec![7]);
    }
}
