//! Deterministic PRNG: SplitMix64 core with uniform / normal sampling.
//!
//! Every stochastic element of the simulator (comparator offset, thermal
//! noise, dataset synthesis, training shuffles) draws from this generator
//! so that any experiment is reproducible from its seed alone.

/// SplitMix64 generator (Steele et al., 2014). Passes BigCrush when used
/// as a 64-bit stream; more than adequate for simulation noise.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of the Box–Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, spare_normal: None }
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0x9e37_79b9_7f4a_7c15)
    }

    /// Deterministic per-stream generator: the same `(seed, stream)` pair
    /// always yields the same sequence, and distinct streams are
    /// decorrelated by the SplitMix64 output scrambler. This is the
    /// batch-sharding contract — sample `i` of a batch draws from
    /// `for_stream(seed, i)` no matter which worker thread (or how many)
    /// processes it, so batch results are thread-count invariant.
    #[inline]
    pub fn for_stream(seed: u64, stream: u64) -> Rng {
        Rng::new(
            seed ^ stream
                .wrapping_add(1)
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .rotate_left(23),
        )
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → exactly representable dyadic rational in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Fair coin.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via the Marsaglia polar method (cached pair).
    /// Trig-free — ~1.7× faster than Box–Muller in the crossbar hot
    /// loop (EXPERIMENTS.md §Perf).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left input unchanged");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn for_stream_is_deterministic_and_distinct() {
        let mut a = Rng::for_stream(42, 7);
        let mut b = Rng::for_stream(42, 7);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut seen = std::collections::BTreeSet::new();
        for stream in 0..64u64 {
            assert!(seen.insert(Rng::for_stream(42, stream).next_u64()));
        }
    }
}
