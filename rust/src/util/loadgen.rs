//! Deterministic load generator for the serving path (`adcim loadgen`).
//!
//! Two classic modes drive an [`EdgeServer`]:
//!
//! - **Open loop** (`LoadMode::Open`): arrivals are paced at a target
//!   QPS regardless of how the server keeps up — the honest way to
//!   measure overload, shedding, and tail latency, because a slow
//!   server cannot push back on the arrival process (coordinated
//!   omission). `burst > 1` groups arrivals into back-to-back bursts
//!   at the same average rate.
//! - **Closed loop** (`LoadMode::Closed`): a fixed number of in-flight
//!   requests; each response immediately triggers the next submit.
//!   Throughput-seeking and self-clocking — the right mode for "how
//!   fast can it go", useless for tail-latency-under-overload claims.
//!
//! Frame *content* is whatever the caller's `submit_one` closure
//! builds (seed it for bit-reproducible runs); only arrival *timing*
//! is wall-clock. Shed and malformed submissions are counted, never
//! retried, so `offered = admitted + shed + malformed` holds exactly
//! and the server's own per-class QoS counters can be checked against
//! the report.

use std::time::{Duration, Instant};

use crate::coordinator::{EdgeServer, InferenceResponse, SubmitError};

/// Arrival process for [`run`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMode {
    /// Paced arrivals at `qps` frames/second in groups of `burst`
    /// (1 = smooth), independent of server progress.
    Open {
        /// Target offered rate, frames per second (≥ 1).
        qps: u64,
        /// Arrivals grouped back-to-back per pacing tick (≥ 1).
        burst: usize,
    },
    /// `concurrency` requests in flight; a response triggers the next
    /// submit.
    Closed {
        /// In-flight window size (≥ 1).
        concurrency: usize,
    },
}

/// One load-generation run.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Arrival process.
    pub mode: LoadMode,
    /// Total frames to offer.
    pub total: u64,
    /// How long to wait for in-flight responses after the last submit
    /// (and per blocking receive in closed mode) before giving up.
    pub drain: Duration,
}

/// What a [`run`] measured. `offered = admitted + shed + malformed`
/// holds exactly; `completed` counts responses received (served +
/// degraded) and can fall short of `admitted` only if the drain window
/// expired.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Frames submitted to the server.
    pub offered: u64,
    /// Frames past admission (a response will arrive for each).
    pub admitted: u64,
    /// Frames refused by graduated admission (`QueueFull`).
    pub shed: u64,
    /// Wire frames refused by ingest validation (`Malformed`; only
    /// nonzero when the submit closure drives `submit_wire`).
    pub malformed: u64,
    /// Responses received within the drain window.
    pub completed: u64,
    /// Responses that were failure answers (degraded), not logits.
    pub degraded: u64,
    /// Every response received, submission order not guaranteed.
    pub responses: Vec<InferenceResponse>,
    /// Wall clock from first submit to last response (or drain expiry).
    pub wall: Duration,
}

impl LoadReport {
    /// Completions per wall-clock second.
    pub fn throughput_per_s(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.completed as f64 / secs
        } else {
            0.0
        }
    }
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "offered={} admitted={} shed={} malformed={} completed={} degraded={} \
             wall={:.3}s rate={:.0}/s",
            self.offered,
            self.admitted,
            self.shed,
            self.malformed,
            self.completed,
            self.degraded,
            self.wall.as_secs_f64(),
            self.throughput_per_s()
        )
    }
}

/// Drive `server` with the arrival process in `spec`. `submit_one(i)`
/// submits the i-th frame (0-based) — typically a closure over
/// [`EdgeServer::submit`] or [`EdgeServer::submit_wire`] with seeded
/// deterministic content; only arrival timing is wall-clock.
pub fn run(
    server: &EdgeServer,
    spec: &LoadSpec,
    submit_one: impl FnMut(u64) -> Result<(), SubmitError>,
) -> LoadReport {
    run_with_tick(server, spec, submit_one, || {})
}

/// [`run`] with a periodic hook: `tick()` fires once per pacing
/// iteration (open loop), per response wait (closed loop), and per
/// drain poll — frequently enough for a cadence-gated observer like
/// [`crate::util::telemetry::TelemetrySink`] to flush on time, without
/// ever sitting on the per-submit fast path.
pub fn run_with_tick(
    server: &EdgeServer,
    spec: &LoadSpec,
    mut submit_one: impl FnMut(u64) -> Result<(), SubmitError>,
    mut tick: impl FnMut(),
) -> LoadReport {
    let start = Instant::now();
    let mut admitted = 0u64;
    let mut shed = 0u64;
    let mut malformed = 0u64;
    let mut responses: Vec<InferenceResponse> = Vec::new();
    let mut offered = 0u64;

    // Returns false only when the server is shutting down (the run
    // cannot make progress); sheds and malformed frames are counted
    // and the offer process moves on.
    let mut submit = |i: u64, admitted: &mut u64, shed: &mut u64, malformed: &mut u64| -> bool {
        match submit_one(i) {
            Ok(()) => {
                *admitted += 1;
                true
            }
            Err(SubmitError::QueueFull) => {
                *shed += 1;
                true
            }
            Err(SubmitError::Malformed(_)) => {
                *malformed += 1;
                true
            }
            Err(SubmitError::Closed) => false,
        }
    };

    match spec.mode {
        LoadMode::Open { qps, burst } => {
            let qps = qps.max(1);
            let burst = burst.max(1) as u64;
            // One pacing tick delivers a whole burst; ticks are spaced
            // so the average rate stays `qps`.
            let pace = Duration::from_nanos(burst.saturating_mul(1_000_000_000) / qps);
            let mut next = start;
            'offer: while offered < spec.total {
                let now = Instant::now();
                if next > now {
                    std::thread::sleep(next - now);
                }
                for _ in 0..burst.min(spec.total - offered) {
                    if !submit(offered, &mut admitted, &mut shed, &mut malformed) {
                        break 'offer;
                    }
                    offered += 1;
                }
                next += pace;
                // Opportunistic drain keeps the response channel short.
                responses.extend(server.take_responses());
                tick();
            }
        }
        LoadMode::Closed { concurrency } => {
            let concurrency = concurrency.max(1) as u64;
            let mut in_flight = 0u64;
            'closed: loop {
                // Fill the window; only admitted frames occupy a slot
                // (a shed frame is gone, the loop moves to the next).
                while offered < spec.total && in_flight < concurrency {
                    let before = admitted;
                    if !submit(offered, &mut admitted, &mut shed, &mut malformed) {
                        break 'closed;
                    }
                    offered += 1;
                    if admitted > before {
                        in_flight += 1;
                    }
                }
                if in_flight == 0 {
                    break;
                }
                tick();
                match server.recv_response(spec.drain) {
                    Some(r) => {
                        responses.push(r);
                        in_flight -= 1;
                    }
                    None => break 'closed, // stalled server: report what we have
                }
            }
        }
    }

    // Drain whatever is still in flight.
    let drain_deadline = Instant::now() + spec.drain;
    while (responses.len() as u64) < admitted && Instant::now() < drain_deadline {
        tick();
        if let Some(r) = server.recv_response(Duration::from_millis(50)) {
            responses.push(r);
        }
    }
    responses.extend(server.take_responses());
    responses.truncate(admitted as usize);

    let degraded = responses.iter().filter(|r| r.error.is_some()).count() as u64;
    LoadReport {
        offered,
        admitted,
        shed,
        malformed,
        completed: responses.len() as u64,
        degraded,
        wall: start.elapsed(),
        responses,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerConfig;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::{InferenceEngine, InferenceRequest, RoutingPolicy};

    fn mock_server(queue_depth: usize, deadline_us: u64) -> EdgeServer {
        let cfg = ServerConfig {
            workers: 2,
            batch: 8,
            batch_deadline_us: deadline_us,
            queue_depth,
            ..Default::default()
        };
        let engines: Vec<Box<dyn InferenceEngine>> = (0..2)
            .map(|_| {
                Box::new(MockEngine {
                    classes: 10,
                    input: 4,
                    delay: Duration::from_micros(50),
                }) as Box<dyn InferenceEngine>
            })
            .collect();
        EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap()
    }

    fn req(i: u64) -> InferenceRequest {
        InferenceRequest::new(i, 0, vec![(i % 10) as f32; 4])
    }

    #[test]
    fn closed_loop_serves_every_frame() {
        let server = mock_server(256, 500);
        let spec = LoadSpec {
            mode: LoadMode::Closed { concurrency: 8 },
            total: 64,
            drain: Duration::from_secs(5),
        };
        let report = run(&server, &spec, |i| server.submit(req(i)));
        assert_eq!(report.offered, 64);
        assert_eq!(report.admitted, 64, "closed loop under depth never sheds");
        assert_eq!(report.shed, 0);
        assert_eq!(report.completed, 64);
        assert_eq!(report.degraded, 0);
        assert_eq!(report.offered, report.admitted + report.shed);
        // Content determinism: every response classifies its own id.
        for r in &report.responses {
            assert_eq!(r.class, (r.id % 10) as usize);
        }
        server.shutdown();
    }

    #[test]
    fn open_loop_offers_everything_and_accounts_exactly() {
        let server = mock_server(256, 500);
        let spec = LoadSpec {
            // Fast but paced: 64 frames in bursts of 16 at 50k qps.
            mode: LoadMode::Open { qps: 50_000, burst: 16 },
            total: 64,
            drain: Duration::from_secs(5),
        };
        let report = run(&server, &spec, |i| server.submit(req(i)));
        assert_eq!(report.offered, 64);
        assert_eq!(report.offered, report.admitted + report.shed);
        assert_eq!(report.completed, report.admitted);
        let line = format!("{report}");
        assert!(line.contains("offered=64"), "{line}");
        server.shutdown();
    }

    /// An open loop into a tiny queue with a stalled batcher sheds —
    /// and the report's accounting identity still holds exactly.
    #[test]
    fn open_loop_overload_sheds_exactly() {
        // Long deadline + big batch: nothing completes during the
        // offer phase, so the queue depth is a pure function of the
        // submission sequence.
        let cfg = ServerConfig {
            workers: 1,
            batch: 64,
            batch_deadline_us: 500_000,
            queue_depth: 8,
            ..Default::default()
        };
        let engines: Vec<Box<dyn InferenceEngine>> = vec![Box::new(MockEngine {
            classes: 10,
            input: 4,
            delay: Duration::from_micros(50),
        })];
        let server = EdgeServer::start(&cfg, engines, RoutingPolicy::RoundRobin).unwrap();
        let spec = LoadSpec {
            mode: LoadMode::Open { qps: 1_000_000, burst: 32 },
            total: 32,
            drain: Duration::from_secs(5),
        };
        let report = run(&server, &spec, |i| server.submit(req(i)));
        assert_eq!(report.offered, 32);
        assert_eq!(report.admitted, 8, "exactly queue_depth admitted");
        assert_eq!(report.shed, 24);
        assert_eq!(report.completed, 8, "admitted frames still answer after the flush");
        server.shutdown();
    }

    /// The tick hook fires on every pacing iteration — often enough
    /// for a cadence-gated exporter — and never changes the report.
    #[test]
    fn tick_hook_fires_per_pacing_iteration() {
        let server = mock_server(256, 500);
        let spec = LoadSpec {
            mode: LoadMode::Open { qps: 50_000, burst: 8 },
            total: 32,
            drain: Duration::from_secs(5),
        };
        let mut ticks = 0u64;
        let report = run_with_tick(&server, &spec, |i| server.submit(req(i)), || ticks += 1);
        assert!(ticks >= 4, "one tick per burst at minimum, got {ticks}");
        assert_eq!(report.offered, 32);
        assert_eq!(report.offered, report.admitted + report.shed);
        server.shutdown();
    }

    /// The closure can drive `submit_wire`: malformed bytes are counted
    /// separately and the accounting identity still closes.
    #[test]
    fn wire_closure_counts_malformed() {
        use crate::frontend::codec::{CodecParams, LOSSLESS};
        use crate::frontend::encoder::{FrameEncoder, Selection};
        let server = mock_server(256, 500);
        let params = CodecParams::new(1, 4, 8, LOSSLESS).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::All);
        let wires: Vec<Vec<u8>> =
            (0..8u64).map(|i| enc.encode_wire(&[(i % 2) as f32, 0.5, 0.25, 0.75], i)).collect();
        let spec = LoadSpec {
            mode: LoadMode::Open { qps: 100_000, burst: 4 },
            total: 8,
            drain: Duration::from_secs(5),
        };
        // Every odd frame is truncated garbage.
        let report = run(&server, &spec, |i| {
            let bytes = &wires[i as usize];
            let bytes = if i % 2 == 1 { &bytes[..bytes.len() - 2] } else { &bytes[..] };
            server.submit_wire(0, bytes).map(|_| ())
        });
        assert_eq!(report.offered, 8);
        assert_eq!(report.malformed, 4);
        assert_eq!(report.admitted, 4);
        assert_eq!(report.offered, report.admitted + report.shed + report.malformed);
        assert_eq!(report.completed, 4);
        server.shutdown();
    }
}
