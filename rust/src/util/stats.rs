//! Streaming statistics, histograms and entropy.
//!
//! Used for MAV-distribution analysis (paper Fig 10), non-ideality
//! characterization (Fig 12) and report tables.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    /// Empty accumulator.
    pub fn new() -> Self {
        Moments { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in (Welford update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }
}

impl std::iter::FromIterator<f64> for Moments {
    fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut m = Moments::new();
        for x in iter {
            m.push(x);
        }
        m
    }
}

/// Fixed-range histogram over [lo, hi) with `bins` equal-width bins.
/// Out-of-range samples clamp into the edge bins (we histogram voltages
/// and codes whose range is known a priori).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Uniform-bin histogram over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    /// Count one sample (out-of-range clamps to the edge bins).
    pub fn push(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Per-bin counts.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total samples counted.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Probability mass per bin.
    pub fn pmf(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts.iter().map(|&c| c as f64 / self.total as f64).collect()
    }

    /// Bin centre of bin `i`.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Render a compact ASCII bar chart (for reports).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!("{:>8.3} | {:<w$} {}\n", self.center(i), bar, c, w = width));
        }
        out
    }
}

/// Standard normal CDF Φ(x) (Abramowitz–Stegun 7.1.26 via erf; max abs
/// error ~1.5e-7 — plenty for yield/dead-cell probabilities).
pub fn normal_cdf(x: f64) -> f64 {
    let t = x / std::f64::consts::SQRT_2;
    0.5 * (1.0 + erf(t))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Shannon entropy (bits) of a probability mass function.
pub fn entropy_bits(pmf: &[f64]) -> f64 {
    pmf.iter().filter(|&&p| p > 0.0).map(|&p| -p * p.log2()).sum()
}

/// Percentile (nearest-rank) of a sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let m: Moments = [1.0, 2.0, 3.0, 4.0].iter().copied().collect();
        assert_eq!(m.count(), 4);
        assert!((m.mean() - 2.5).abs() < 1e-12);
        assert!((m.var() - 1.25).abs() < 1e-12);
        assert_eq!(m.min(), 1.0);
        assert_eq!(m.max(), 4.0);
    }

    #[test]
    fn histogram_bins_and_pmf() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for x in [0.1, 0.1, 0.4, 0.6, 0.9] {
            h.push(x);
        }
        assert_eq!(h.counts(), &[2, 1, 1, 1]);
        let pmf = h.pmf();
        assert!((pmf.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.push(-5.0);
        h.push(5.0);
        assert_eq!(h.counts(), &[1, 1]);
    }

    #[test]
    fn entropy_uniform_is_log2_n() {
        let pmf = vec![0.25; 4];
        assert!((entropy_bits(&pmf) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_point_mass_is_zero() {
        assert_eq!(entropy_bits(&[1.0, 0.0]), 0.0);
    }

    #[test]
    fn normal_cdf_known_points() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
        assert!(normal_cdf(6.0) > 0.999_999);
        assert!(normal_cdf(-6.0) < 1e-6);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 50.0), 3.0);
        assert_eq!(percentile_sorted(&v, 100.0), 5.0);
    }
}
