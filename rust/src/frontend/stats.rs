//! Frontend accounting: what the deluge was, and what survived it.
//!
//! [`FrontendStats`] counts frames in/kept/summarized/dropped and bytes
//! in/out, and histograms the retained-energy fraction of every encoded
//! frame. It is mergeable (worker/shard deltas) and threads into
//! [`crate::coordinator::Metrics`] next to the pool's conversion
//! counters, so one `MetricsSnapshot` line shows both halves of the
//! paper's story: fewer bytes in, fewer conversions downstream.

/// Histogram bins over the retained-energy fraction [0, 1].
pub const RETAINED_BINS: usize = 8;

/// Mergeable frontend counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FrontendStats {
    /// Frames offered to the frontend.
    pub frames_in: u64,
    /// Frames forwarded as [`super::CompressedFrame`]s.
    pub kept: u64,
    /// Frames reduced to a [`super::FrameSummary`].
    pub summarized: u64,
    /// Frames shed entirely.
    pub dropped: u64,
    /// Raw sensor bytes offered (dense f32 frames).
    pub bytes_in: u64,
    /// Bytes forwarded downstream: kept compressed frames plus the
    /// summaries that replace summarized frames (what crosses the
    /// sensor link — whether the driver persists summaries is its
    /// business; `adcim serve` prints a digest of them).
    pub bytes_out: u64,
    /// Retained-energy histogram: bin `i` counts encoded frames with
    /// retained fraction in `[i/8, (i+1)/8)` (1.0 lands in the last bin).
    pub retained_hist: [u64; RETAINED_BINS],
}

impl FrontendStats {
    /// Fold another counter set into this one.
    pub fn merge(&mut self, other: &FrontendStats) {
        self.frames_in += other.frames_in;
        self.kept += other.kept;
        self.summarized += other.summarized;
        self.dropped += other.dropped;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        for (a, b) in self.retained_hist.iter_mut().zip(&other.retained_hist) {
            *a += b;
        }
    }

    /// Record one encoded frame's retained-energy fraction.
    pub fn record_retained(&mut self, fraction: f32) {
        let bin = ((fraction.clamp(0.0, 1.0) * RETAINED_BINS as f32) as usize)
            .min(RETAINED_BINS - 1);
        self.retained_hist[bin] += 1;
    }

    /// Ingest-byte reduction factor (bytes in / bytes out). 1.0 when
    /// nothing has flowed in; total containment (`bytes_out == 0` with
    /// traffic) reports the full `bytes_in` factor rather than
    /// pretending no reduction happened.
    pub fn compression_ratio(&self) -> f64 {
        if self.bytes_in == 0 {
            1.0
        } else {
            self.bytes_in as f64 / self.bytes_out.max(1) as f64
        }
    }

    /// Mean retained-energy fraction estimate from the histogram
    /// (bin centres).
    pub fn retained_mean(&self) -> f64 {
        let n: u64 = self.retained_hist.iter().sum();
        if n == 0 {
            return 0.0;
        }
        let num: f64 = self
            .retained_hist
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as f64 + 0.5) / RETAINED_BINS as f64 * c as f64)
            .sum();
        num / n as f64
    }
}

impl std::fmt::Display for FrontendStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "frontend: in={} kept={} sum={} drop={} bytes={}→{} ({:.1}x) retained~{:.2}",
            self.frames_in,
            self.kept,
            self.summarized,
            self.dropped,
            self.bytes_in,
            self.bytes_out,
            self.compression_ratio(),
            self.retained_mean()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_everything() {
        let mut a = FrontendStats {
            frames_in: 4,
            kept: 2,
            summarized: 1,
            dropped: 1,
            bytes_in: 4096,
            bytes_out: 512,
            ..Default::default()
        };
        a.record_retained(0.9);
        let mut b = FrontendStats {
            frames_in: 1,
            bytes_in: 1024,
            bytes_out: 64,
            ..Default::default()
        };
        b.record_retained(0.1);
        a.merge(&b);
        assert_eq!(a.frames_in, 5);
        assert_eq!(a.bytes_in, 5120);
        assert_eq!(a.bytes_out, 576);
        assert_eq!(a.retained_hist.iter().sum::<u64>(), 2);
        assert_eq!(a.retained_hist[7], 1);
        assert_eq!(a.retained_hist[0], 1);
    }

    #[test]
    fn ratio_and_hist_edges() {
        let mut s = FrontendStats::default();
        assert_eq!(s.compression_ratio(), 1.0);
        s.bytes_in = 1000;
        s.bytes_out = 100;
        assert!((s.compression_ratio() - 10.0).abs() < 1e-12);
        // Total containment: everything dropped is the best ratio, not
        // "1.0x".
        s.bytes_out = 0;
        assert!((s.compression_ratio() - 1000.0).abs() < 1e-12);
        s.bytes_out = 100;
        s.record_retained(1.0); // lands in the last bin, not out of range
        s.record_retained(-0.5);
        assert_eq!(s.retained_hist[RETAINED_BINS - 1], 1);
        assert_eq!(s.retained_hist[0], 1);
        assert!((s.retained_mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn display_shows_the_flow() {
        let s = FrontendStats {
            frames_in: 10,
            kept: 8,
            summarized: 1,
            dropped: 1,
            bytes_in: 4000,
            bytes_out: 400,
            ..Default::default()
        };
        let line = format!("{s}");
        assert!(line.contains("in=10"), "{line}");
        assert!(line.contains("kept=8"), "{line}");
        assert!(line.contains("10.0x"), "{line}");
    }
}
