//! Retention policy: per-frame keep / summarize / drop triage.
//!
//! The deluge-containment decision the paper motivates (§I, §II-A):
//! after encoding, each frame carries three cheap scores —
//!
//! - `ac_retained` — fraction of AC sequency energy the kept
//!   coefficients capture. Structured scenes (oriented gratings, edges)
//!   concentrate; sensor noise spreads flat.
//! - `peak_to_mean` — peak |AC coefficient| over the mean: the
//!   classifier-margin proxy (a dominant sequency line is what the
//!   downstream BWHT classifier keys on).
//! - `ac_energy` — absolute AC energy: the dead-sensor / blank-scene
//!   floor.
//!
//! [`RetentionPolicy::decide`] maps scores to a [`Verdict`]: **Keep**
//! (forward the compressed frame to serving), **Summarize** (retain a
//! tiny [`FrameSummary`] — per-channel DC plus energy — and shed the
//! rest), or **Drop** (nothing survives). `KeepAll` is the
//! policy-disabled baseline every byte-accounting comparison runs
//! against.

use super::codec::CompressedFrame;

/// What survives of a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Forward the full compressed frame to serving.
    Keep,
    /// Retain only a tiny [`FrameSummary`]; shed the coefficients.
    Summarize,
    /// Nothing survives.
    Drop,
}

/// Per-frame retention rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RetentionPolicy {
    /// Every encoded frame is kept (compression only, no shedding).
    KeepAll,
    /// Score-based triage.
    Triage {
        /// Keep when `ac_retained` reaches this (structure concentrates
        /// in the kept coefficients)…
        keep_above: f32,
        /// …or when `peak_to_mean` reaches this margin proxy.
        margin: f32,
        /// Drop when `ac_retained` falls below this (and the margin
        /// test failed); scores in between summarize.
        drop_below: f32,
        /// Frames with AC energy under this floor drop outright
        /// (blank scene / dead sensor), regardless of shape scores.
        min_ac_energy: f32,
    },
}

impl RetentionPolicy {
    /// The default triage operating point used by `--retain triage`.
    pub fn triage_default() -> Self {
        RetentionPolicy::Triage {
            keep_above: 0.55,
            margin: 8.0,
            drop_below: 0.30,
            min_ac_energy: 1e-4,
        }
    }

    /// Parse a CLI/config policy name: `keep`/`all` or `triage`/`energy`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "keep" | "all" => Ok(RetentionPolicy::KeepAll),
            "triage" | "energy" => Ok(RetentionPolicy::triage_default()),
            other => Err(format!("unknown retention policy '{other}' (want keep | triage)")),
        }
    }

    /// Triage one encoded frame.
    pub fn decide(&self, f: &CompressedFrame) -> Verdict {
        match *self {
            RetentionPolicy::KeepAll => Verdict::Keep,
            RetentionPolicy::Triage { keep_above, margin, drop_below, min_ac_energy } => {
                if f.ac_energy < min_ac_energy {
                    return Verdict::Drop;
                }
                if f.ac_retained >= keep_above || f.peak_to_mean >= margin {
                    return Verdict::Keep;
                }
                if f.ac_retained < drop_below {
                    Verdict::Drop
                } else {
                    Verdict::Summarize
                }
            }
        }
    }

    /// QoS priority for graduated admission
    /// ([`crate::coordinator::backpressure::admissible`]), derived from
    /// the same scores [`Self::decide`] triages on. The verdict picks
    /// the band — Keep ⇒ 192..=255, Summarize ⇒ 64..=191,
    /// Drop ⇒ 0..=63 — and `ac_retained`'s position inside the
    /// verdict's score interval picks the level within the band, so
    /// under overload the least-structured frames shed first and
    /// Keep-class traffic sheds last. `KeepAll` (the policy-disabled
    /// baseline) pins everything to 255, which makes graduated
    /// admission bit-identical to the legacy full-queue check.
    pub fn priority(&self, f: &CompressedFrame) -> u8 {
        // Linear position of `t` in [0,1] mapped onto lo..=hi; NaN and
        // out-of-range scores clamp to the band edges.
        fn band(lo: u8, hi: u8, t: f32) -> u8 {
            let t = if t.is_finite() { t.clamp(0.0, 1.0) } else { 0.0 };
            lo + (t * (hi - lo) as f32) as u8
        }
        match *self {
            RetentionPolicy::KeepAll => u8::MAX,
            RetentionPolicy::Triage { keep_above, drop_below, .. } => match self.decide(f) {
                Verdict::Keep => {
                    let span = (1.0 - keep_above).max(f32::EPSILON);
                    band(192, 255, (f.ac_retained - keep_above) / span)
                }
                Verdict::Summarize => {
                    let span = (keep_above - drop_below).max(f32::EPSILON);
                    band(64, 191, (f.ac_retained - drop_below) / span)
                }
                Verdict::Drop => band(0, 63, f.ac_retained / drop_below.max(f32::EPSILON)),
            },
        }
    }
}

/// The few bytes that survive a summarized frame: identity, per-channel
/// mean level, and energy — enough for drift/occupancy monitoring
/// without the pixels.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameSummary {
    /// Id of the summarized frame.
    pub frame_id: u64,
    /// Originating sensor stream.
    pub stream: u32,
    /// Mean level per channel (the DC the scene kept).
    pub channel_mean: Vec<f32>,
    /// Mean-removed energy per sample.
    pub ac_energy: f32,
}

impl FrameSummary {
    /// Build from the raw dense frame (channel-major).
    pub fn of(frame_id: u64, stream: u32, frame: &[f32], channels: usize) -> Self {
        assert!(channels > 0 && frame.len() % channels == 0);
        let samples = frame.len() / channels;
        let channel_mean: Vec<f32> = frame
            .chunks_exact(samples)
            .map(|c| c.iter().sum::<f32>() / samples as f32)
            .collect();
        let mut ac = 0.0f64;
        for (ch, chunk) in frame.chunks_exact(samples).enumerate() {
            let m = channel_mean[ch];
            for &v in chunk {
                ac += ((v - m) as f64) * ((v - m) as f64);
            }
        }
        FrameSummary {
            frame_id,
            stream,
            channel_mean,
            ac_energy: (ac / frame.len() as f64) as f32,
        }
    }

    /// Wire size: id (8) + stream (4) + energy (4) + per-channel means.
    pub fn encoded_bytes(&self) -> usize {
        16 + self.channel_mean.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::codec::CodecParams;
    use crate::frontend::encoder::{FrameEncoder, Selection};
    use crate::util::Rng;

    fn encode(frame: &[f32], channels: usize, samples: usize, k: usize) -> CompressedFrame {
        let p = CodecParams::new(channels, samples, 8, 8).unwrap();
        FrameEncoder::new(p, Selection::TopK(k)).encode(frame, 0)
    }

    /// A structured frame keeps, a blank frame drops, mid-grade noise
    /// summarizes — the three verdicts on synthetic archetypes.
    #[test]
    fn triage_separates_archetypes() {
        let policy = RetentionPolicy::triage_default();
        let n = 64usize;

        // Structured: a square wave — concentrates in few sequency bins.
        let structured: Vec<f32> =
            (0..n).map(|i| if (i / 4) % 2 == 0 { 0.9 } else { 0.1 }).collect();
        assert_eq!(policy.decide(&encode(&structured, 1, n, 8)), Verdict::Keep);

        // Blank: constant scene, AC energy under the floor.
        let blank = vec![0.5f32; n];
        assert_eq!(policy.decide(&encode(&blank, 1, n, 8)), Verdict::Drop);

        // Broadband noise at K=4 of 64: spread energy, weak peak. Lands
        // below keep_above; whether it summarizes or drops depends on
        // the tail the top-4 capture — never Keep.
        let mut rng = Rng::new(5);
        let noise: Vec<f32> =
            (0..n).map(|_| (0.5 + 0.25 * rng.normal()) as f32).collect();
        assert_ne!(policy.decide(&encode(&noise, 1, n, 4)), Verdict::Keep);
    }

    /// Priorities land in the band their verdict dictates, so
    /// graduated shedding orders frames the way triage would.
    #[test]
    fn priority_bands_follow_verdicts() {
        let policy = RetentionPolicy::triage_default();
        let n = 64usize;
        let structured: Vec<f32> =
            (0..n).map(|i| if (i / 4) % 2 == 0 { 0.9 } else { 0.1 }).collect();
        let blank = vec![0.5f32; n];
        let mut rng = Rng::new(5);
        let noise: Vec<f32> = (0..n).map(|_| (0.5 + 0.25 * rng.normal()) as f32).collect();

        for (frame, k) in [(&structured, 8usize), (&blank, 8), (&noise, 4)] {
            let cf = encode(frame, 1, n, k);
            let p = policy.priority(&cf);
            match policy.decide(&cf) {
                Verdict::Keep => assert!(p >= 192, "Keep frame priority {p} below band"),
                Verdict::Summarize => {
                    assert!((64..=191).contains(&p), "Summarize priority {p} out of band")
                }
                Verdict::Drop => assert!(p <= 63, "Drop frame priority {p} above band"),
            }
            // KeepAll pins top priority regardless of scores.
            assert_eq!(RetentionPolicy::KeepAll.priority(&cf), u8::MAX);
        }
    }

    #[test]
    fn keep_all_keeps_everything() {
        let blank = vec![0.0f32; 32];
        let cf = encode(&blank, 1, 32, 4);
        assert_eq!(RetentionPolicy::KeepAll.decide(&cf), Verdict::Keep);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(RetentionPolicy::parse("keep").unwrap(), RetentionPolicy::KeepAll);
        assert_eq!(
            RetentionPolicy::parse("triage").unwrap(),
            RetentionPolicy::triage_default()
        );
        assert!(RetentionPolicy::parse("yolo").is_err());
    }

    #[test]
    fn summary_captures_means_and_bytes() {
        let frame = [0.0f32, 0.2, 0.4, 0.6, 1.0, 1.0, 1.0, 1.0];
        let s = FrameSummary::of(9, 3, &frame, 2);
        assert_eq!(s.frame_id, 9);
        assert_eq!(s.stream, 3);
        assert!((s.channel_mean[0] - 0.3).abs() < 1e-6);
        assert!((s.channel_mean[1] - 1.0).abs() < 1e-6);
        assert_eq!(s.encoded_bytes(), 16 + 8);
        assert!(s.ac_energy > 0.0);
        // Far smaller than the raw frame.
        assert!(s.encoded_bytes() < frame.len() * 4);
    }
}
