//! Sequency-domain frame encoder: snap → per-channel Walsh–Hadamard →
//! coefficient selection → pack.
//!
//! This is the frontend's compute core. Each channel of an incoming
//! frame is snapped to the sensor grid, transformed with the *sequency*
//! ordered FWHT (`wht::fwht_sequency_inplace` — same substrate the BWHT
//! serving layers run on), and then a [`Selection`] rule decides which
//! coefficients survive the deluge: all non-zeros, the global top-K by
//! magnitude, or the smallest set reaching an energy fraction. The
//! survivors are packed by [`super::codec`] with per-band quantization.
//!
//! Selection is *global across channels* — one budget for the whole
//! frame — so an uninformative channel naturally yields its bits to an
//! informative one, and fully-dropped channels decode (and serve) for
//! free.
//!
//! Determinism: encoding is a pure function of `(frame, frame_id,
//! config)`. With `dither` enabled the quantizer's dither stream is
//! `Rng::for_stream(seed, frame_id)` — the same contract the analog
//! serving path uses for noise, so re-encoding a frame id reproduces
//! its bits no matter how streams interleave.

use crate::util::Rng;
use crate::wht::fwht_sequency_inplace;

use super::codec::{band_map_set, BitWriter, CodecParams, CompressedFrame, LOSSLESS};

/// Which coefficients survive encoding.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Selection {
    /// Keep every non-zero coefficient (exact; zero compression).
    All,
    /// Keep the `K` largest-magnitude coefficients frame-wide.
    TopK(usize),
    /// Keep the smallest prefix (by magnitude) reaching this fraction
    /// of total coefficient energy, in (0, 1].
    EnergyFrac(f32),
}

impl Selection {
    /// Parse `"all"`, `"topN"` (e.g. `top32`) or `"eF"` (e.g. `e0.95`).
    pub fn parse(s: &str) -> Result<Self, String> {
        if s.eq_ignore_ascii_case("all") {
            return Ok(Selection::All);
        }
        if let Some(k) = s.strip_prefix("top") {
            let k: usize = k.parse().map_err(|_| format!("bad top-K selection '{s}'"))?;
            if k == 0 {
                return Err("top-K selection needs K >= 1".to_string());
            }
            return Ok(Selection::TopK(k));
        }
        if let Some(f) = s.strip_prefix('e') {
            let f: f32 = f.parse().map_err(|_| format!("bad energy selection '{s}'"))?;
            if !(0.0..=1.0).contains(&f) || f == 0.0 {
                return Err(format!("energy fraction {f} outside (0, 1]"));
            }
            return Ok(Selection::EnergyFrac(f));
        }
        Err(format!("unknown selection '{s}' (want all | topK | eF)"))
    }
}

/// Streaming frame encoder with reusable scratch.
#[derive(Debug, Clone)]
pub struct FrameEncoder {
    params: CodecParams,
    /// Which coefficients survive (All / TopK / EnergyFrac).
    pub selection: Selection,
    /// Add ±half-step uniform dither before rounding quantized levels
    /// (decorrelates quantization error across a stream). Lossless mode
    /// ignores it.
    pub dither: bool,
    /// Seed of the per-frame dither stream (`Rng::for_stream(seed, id)`).
    pub seed: u64,
    // scratch, reused across frames
    coeffs: Vec<f32>,
    order: Vec<u32>,
}

impl FrameEncoder {
    /// Encoder with dither off.
    pub fn new(params: CodecParams, selection: Selection) -> Self {
        FrameEncoder {
            params,
            selection,
            dither: false,
            seed: 0,
            coeffs: Vec::new(),
            order: Vec::new(),
        }
    }

    /// The codec geometry.
    pub fn params(&self) -> CodecParams {
        self.params
    }

    /// Encode one dense frame (`channels · samples` values, channel
    /// major). Deterministic per `(frame, frame_id)`.
    pub fn encode(&mut self, frame: &[f32], frame_id: u64) -> CompressedFrame {
        let p = self.params;
        assert_eq!(frame.len(), p.dense_len(), "frame length != channels * samples");
        let block = p.block();
        let space = p.coeff_space();

        // 1. Snap to the sensor grid, pad, transform each channel.
        self.coeffs.clear();
        self.coeffs.resize(space, 0.0);
        for ch in 0..p.channels {
            let dst = &mut self.coeffs[ch * block..ch * block + p.samples];
            for (d, &v) in dst.iter_mut().zip(&frame[ch * p.samples..(ch + 1) * p.samples]) {
                *d = p.snap(v);
            }
            fwht_sequency_inplace(&mut self.coeffs[ch * block..(ch + 1) * block]);
        }

        // 2. Energy bookkeeping (f64 accumulators: block² values).
        let mut total_e = 0.0f64;
        let mut ac_e = 0.0f64;
        let mut ac_peak = 0.0f32;
        let mut ac_abs_sum = 0.0f64;
        let mut ac_n = 0u32;
        for (i, &v) in self.coeffs.iter().enumerate() {
            let e = (v as f64) * (v as f64);
            total_e += e;
            if i % block != 0 {
                ac_e += e;
                ac_peak = ac_peak.max(v.abs());
                ac_abs_sum += v.abs() as f64;
                ac_n += 1;
            }
        }

        // 3. Candidate selection: magnitude descending, index ascending
        //    on ties (a deterministic total order — snap sanitizes
        //    non-finite, total_cmp stays panic-free regardless), zeros
        //    excluded (they decode free). Only EnergyFrac needs a full
        //    sort; TopK uses an O(n) partition (the ingest hot path —
        //    the sort was the dominant encode cost).
        self.order.clear();
        self.order.extend((0..space as u32).filter(|&i| self.coeffs[i as usize] != 0.0));
        let coeffs = &self.coeffs;
        let by_magnitude = |a: &u32, b: &u32| {
            let (ea, eb) = (coeffs[*a as usize].abs(), coeffs[*b as usize].abs());
            eb.total_cmp(&ea).then(a.cmp(b))
        };
        let n_keep = match self.selection {
            Selection::All => self.order.len(),
            Selection::TopK(k) => {
                let k = k.min(self.order.len());
                if k > 0 && k < self.order.len() {
                    // Partition so the first k entries are exactly the
                    // top-k under the total order (their internal order
                    // is irrelevant — packing re-sorts by index).
                    self.order.select_nth_unstable_by(k - 1, by_magnitude);
                }
                k
            }
            Selection::EnergyFrac(f) => {
                self.order.sort_unstable_by(by_magnitude);
                let target = f as f64 * total_e;
                let mut cum = 0.0f64;
                let mut n = 0usize;
                for &i in &self.order {
                    if cum >= target {
                        break;
                    }
                    let v = coeffs[i as usize] as f64;
                    cum += v * v;
                    n += 1;
                }
                n.max(usize::from(!self.order.is_empty()))
            }
        };
        let kept = &mut self.order[..n_keep];
        kept.sort_unstable();

        // 4. Kept-energy stats.
        let mut kept_e = 0.0f64;
        let mut kept_ac_e = 0.0f64;
        for &i in kept.iter() {
            let v = coeffs[i as usize] as f64;
            kept_e += v * v;
            if (i as usize) % block != 0 {
                kept_ac_e += v * v;
            }
        }

        // 5. Pack.
        let lossless = p.codec_bits == LOSSLESS;
        let (band_map, scales) = if lossless {
            (Vec::new(), Vec::new())
        } else {
            let mut map = vec![0u8; (p.channels * p.bands()).div_ceil(8)];
            let mut max_abs = vec![0.0f32; p.channels * p.bands()];
            for &i in kept.iter() {
                let (ch, s) = (i as usize / block, i as usize % block);
                let flat = ch * p.bands() + p.band_of(s);
                band_map_set(&mut map, flat);
                max_abs[flat] = max_abs[flat].max(coeffs[i as usize].abs());
            }
            let scales: Vec<f32> = max_abs
                .iter()
                .enumerate()
                .filter(|&(flat, _)| map[flat / 8] & (1 << (flat % 8)) != 0)
                .map(|(_, &m)| m)
                .collect();
            (map, scales)
        };
        let mut writer = BitWriter::default();
        let idx_bits = p.index_bits();
        if lossless {
            for &i in kept.iter() {
                writer.push(i as u64, idx_bits);
                writer.push(coeffs[i as usize].to_bits() as u64, 32);
            }
        } else {
            let max_level = (1i64 << (p.codec_bits - 1)) - 1;
            let mut dither = self.dither.then(|| Rng::for_stream(self.seed, frame_id));
            // Re-derive each coefficient's band scale by rank (same
            // prefix-count rule the decoder uses).
            let mut rank_of = vec![usize::MAX; p.channels * p.bands()];
            {
                let mut rank = 0usize;
                for (flat, slot) in rank_of.iter_mut().enumerate() {
                    if band_map[flat / 8] & (1 << (flat % 8)) != 0 {
                        *slot = rank;
                        rank += 1;
                    }
                }
            }
            for &i in kept.iter() {
                let (ch, s) = (i as usize / block, i as usize % block);
                let scale = scales[rank_of[ch * p.bands() + p.band_of(s)]];
                let v = coeffs[i as usize];
                let level = if scale > 0.0 {
                    let t = v / scale * max_level as f32;
                    let jitter = dither
                        .as_mut()
                        .map(|r| (r.uniform() - 0.5) as f32)
                        .unwrap_or(0.0);
                    ((t + jitter).round() as i64).clamp(-max_level, max_level)
                } else {
                    0
                };
                writer.push(i as u64, idx_bits);
                writer.push((level + max_level) as u64, p.codec_bits as u32);
            }
        }

        let mut out = CompressedFrame::from_parts(
            frame_id,
            p,
            n_keep,
            band_map,
            scales,
            writer.into_bytes(),
        );
        out.retained_energy = if total_e > 0.0 { (kept_e / total_e) as f32 } else { 1.0 };
        out.ac_retained = if ac_e > 1e-12 { (kept_ac_e / ac_e) as f32 } else { 0.0 };
        out.peak_to_mean = if ac_n > 0 && ac_abs_sum > 1e-12 {
            (ac_peak as f64 / (ac_abs_sum / ac_n as f64)) as f32
        } else {
            0.0
        };
        out.ac_energy = (ac_e / block as f64) as f32;
        out
    }

    /// Encode straight to wire bytes (what a sensor node would put on
    /// the link; the triage scores stay node-local).
    pub fn encode_wire(&mut self, frame: &[f32], frame_id: u64) -> Vec<u8> {
        self.encode(frame, frame_id).to_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn params(ch: usize, samples: usize, codec_bits: u8) -> CodecParams {
        CodecParams::new(ch, samples, 8, codec_bits).unwrap()
    }

    fn ramp_frame(p: CodecParams, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..p.dense_len()).map(|_| rng.uniform() as f32).collect()
    }

    fn snapped(p: CodecParams, frame: &[f32]) -> Vec<f32> {
        frame.iter().map(|&v| p.snap(v)).collect()
    }

    /// Lossless + keep-all decodes bit-exactly to the snapped frame.
    #[test]
    fn lossless_round_trip_is_bit_exact() {
        for (ch, samples) in [(1usize, 144usize), (4, 64), (3, 33), (1, 1), (2, 256)] {
            let p = params(ch, samples, LOSSLESS);
            let mut enc = FrameEncoder::new(p, Selection::All);
            let frame = ramp_frame(p, 7 + ch as u64);
            let cf = enc.encode(&frame, 0);
            assert_eq!(cf.decode(), snapped(p, &frame), "ch={ch} samples={samples}");
            assert!((cf.retained_energy - 1.0).abs() < 1e-6);
        }
    }

    /// Property: quantized round-trip error obeys the analytic bound
    /// from dropped energy + per-coefficient quantizer step (Parseval).
    #[test]
    fn quantized_error_is_bounded() {
        prop::check("codec error bound", 64, |rng| {
            let bits = 4 + rng.index(5) as u8; // 4..=8
            let k = 1 + rng.index(48);
            let p = params(2, 32, bits);
            let mut enc = FrameEncoder::new(p, Selection::TopK(k));
            let frame: Vec<f32> = (0..p.dense_len()).map(|_| rng.uniform() as f32).collect();
            let cf = enc.encode(&frame, 3);
            let snap = frame.iter().map(|&v| p.snap(v)).collect::<Vec<_>>();
            let dec = cf.decode();

            // Transform-domain error budget: dropped energy plus one
            // half quantizer step per kept coefficient (no dither).
            let block = p.block() as f64;
            let mut total_e = 0.0f64;
            let mut scale_max = 0.0f64;
            for chn in snap.chunks(p.samples) {
                let mut buf = vec![0.0f32; p.block()];
                buf[..chn.len()].copy_from_slice(chn);
                crate::wht::fwht_sequency_inplace(&mut buf);
                for v in &buf {
                    total_e += (*v as f64) * (*v as f64);
                    scale_max = scale_max.max(v.abs() as f64);
                }
            }
            let dropped = (1.0 - cf.retained_energy as f64).max(0.0) * total_e;
            let max_level = ((1i64 << (bits - 1)) - 1) as f64;
            let step = scale_max / max_level;
            let budget = dropped + cf.kept as f64 * (0.5 * step + 1e-4) * (0.5 * step + 1e-4);
            let err_sq: f64 = dec
                .iter()
                .zip(&snap)
                .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
                .sum();
            // Spatial error = transform error / block (Parseval). The
            // slack absorbs f32 rounding in the stored retained-energy
            // fraction used to reconstruct the dropped-energy term.
            let slack = 1e-6 * (1.0 + total_e / block);
            crate::prop_assert!(
                err_sq <= budget / block + slack,
                "bits={bits} k={k}: err {err_sq} > budget {}",
                budget / block + slack
            );
            Ok(())
        });
    }

    /// The scatter-based decode is bit-identical to the reference path
    /// through `wht::fwht_sequency_inverse_inplace` (same permutation,
    /// same butterfly, same exact 1/m scale).
    #[test]
    fn decode_matches_reference_sequency_inverse() {
        let p = params(3, 64, 8);
        let mut enc = FrameEncoder::new(p, Selection::TopK(20));
        let cf = enc.encode(&ramp_frame(p, 13), 0);
        let block = p.block();
        let mut freq = vec![0.0f32; p.coeff_space()];
        cf.for_each_coeff(|ch, s, v| freq[ch * block + s] = v);
        let mut want = Vec::new();
        for chunk in freq.chunks_exact_mut(block) {
            crate::wht::fwht_sequency_inverse_inplace(chunk);
            want.extend_from_slice(&chunk[..p.samples]);
        }
        assert_eq!(cf.decode(), want);
    }

    #[test]
    fn topk_keeps_exactly_k_and_is_sorted() {
        let p = params(2, 64, 8);
        let mut enc = FrameEncoder::new(p, Selection::TopK(10));
        let cf = enc.encode(&ramp_frame(p, 3), 0);
        assert_eq!(cf.kept, 10);
        let mut last = None;
        let mut seen = 0;
        cf.for_each_coeff(|ch, s, _| {
            let idx = ch * p.block() + s;
            if let Some(prev) = last {
                assert!(idx > prev, "indices must ascend");
            }
            last = Some(idx);
            seen += 1;
        });
        assert_eq!(seen, 10);
    }

    #[test]
    fn energy_fraction_reaches_target() {
        let p = params(2, 64, 8);
        let mut enc = FrameEncoder::new(p, Selection::EnergyFrac(0.9));
        let cf = enc.encode(&ramp_frame(p, 11), 0);
        assert!(cf.retained_energy >= 0.9 - 1e-5, "retained {}", cf.retained_energy);
        assert!(cf.kept < p.coeff_space(), "0.9 target should not need every coefficient");
    }

    #[test]
    fn selection_shrinks_encoded_bytes() {
        let p = params(4, 64, 8);
        let frame = ramp_frame(p, 5);
        let all = FrameEncoder::new(p, Selection::All).encode(&frame, 0);
        let k16 = FrameEncoder::new(p, Selection::TopK(16)).encode(&frame, 0);
        assert!(k16.encoded_bytes() < all.encoded_bytes() / 4);
        assert!((k16.encoded_bytes() as f64) < p.raw_frame_bytes() as f64 / 5.0);
    }

    /// Encoding is deterministic per (frame, id) — including the dither
    /// stream, which follows the `Rng::for_stream` contract.
    #[test]
    fn dithered_encoding_is_deterministic_per_frame_id() {
        let p = params(2, 64, 6);
        let frame = ramp_frame(p, 9);
        let mk = || {
            let mut e = FrameEncoder::new(p, Selection::TopK(24));
            e.dither = true;
            e.seed = 0xd17;
            e
        };
        let a = mk().encode(&frame, 41);
        let b = mk().encode(&frame, 41);
        assert_eq!(a, b, "same (frame, id) must encode identically");
        // And the stream really is per-id: another id may dither
        // differently, but stays self-consistent.
        let c = mk().encode(&frame, 42);
        let d = mk().encode(&frame, 42);
        assert_eq!(c, d);
    }

    /// Faulty-sensor input (NaN/±inf) must not panic the ingest path:
    /// snap sanitizes to 0 and the total-order sort stays total.
    #[test]
    fn non_finite_sensor_values_encode_as_zero() {
        let p = params(1, 8, LOSSLESS);
        let mut enc = FrameEncoder::new(p, Selection::All);
        let frame = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.5, 0.25, 0.0, 1.0, 0.75];
        let dec = enc.encode(&frame, 0).decode();
        assert_eq!(dec, vec![0.0, 0.0, 0.0, 0.5, 0.25, 0.0, 1.0, 0.75]);
    }

    /// `from_bytes(to_bytes(f))` across the codec parameter grid: the
    /// wire carries everything serving needs (the decode is identical),
    /// and re-serializing reproduces the exact bytes.
    #[test]
    fn wire_round_trip_across_parameter_grid() {
        let mut id = 0u64;
        for &(ch, samples) in &[(1usize, 144usize), (4, 64), (3, 33), (1, 1), (2, 256)] {
            for &bits in &[LOSSLESS, 2, 6, 8, 16] {
                for sel in [Selection::All, Selection::TopK(9), Selection::EnergyFrac(0.8)] {
                    for dither in [false, true] {
                        let p = params(ch, samples, bits);
                        let mut enc = FrameEncoder::new(p, sel);
                        enc.dither = dither;
                        enc.seed = 0xabc;
                        let frame = ramp_frame(p, 21 + id);
                        id += 1;
                        let cf = enc.encode(&frame, id);
                        let wire = enc.encode_wire(&frame, id);
                        assert_eq!(wire, cf.to_bytes());
                        assert_eq!(wire.len(), cf.encoded_bytes());
                        let back = crate::frontend::CompressedFrame::from_bytes(&wire)
                            .unwrap_or_else(|e| {
                                panic!("ch={ch} samples={samples} bits={bits}: {e}")
                            });
                        assert_eq!(back.to_bytes(), wire);
                        assert_eq!(back.decode(), cf.decode());
                    }
                }
            }
        }
    }

    #[test]
    fn selection_parse() {
        assert_eq!(Selection::parse("all").unwrap(), Selection::All);
        assert_eq!(Selection::parse("top32").unwrap(), Selection::TopK(32));
        assert_eq!(Selection::parse("e0.95").unwrap(), Selection::EnergyFrac(0.95));
        assert!(Selection::parse("top0").is_err());
        assert!(Selection::parse("e1.5").is_err());
        assert!(Selection::parse("bogus").is_err());
    }

    #[test]
    fn flat_frame_scores_as_unstructured() {
        let p = params(2, 64, 8);
        let mut enc = FrameEncoder::new(p, Selection::TopK(16));
        let cf = enc.encode(&vec![0.5f32; p.dense_len()], 0);
        assert_eq!(cf.ac_retained, 0.0);
        assert_eq!(cf.peak_to_mean, 0.0);
        assert!(cf.ac_energy < 1e-9);
        // The DC coefficients still decode the frame.
        assert_eq!(cf.decode(), vec![0.5f32; p.dense_len()]);
    }
}
