//! Frequency-domain sensor frontend (paper §II-A): contain the analog
//! data deluge *before* it reaches the serving queue.
//!
//! PRs 1–3 made the serving fabric fast and collaborative, but every
//! frame still arrived as a dense `Vec<f32>` — the only deluge response
//! was backpressure shedding whole frames blind. This subsystem is the
//! paper's titular answer: encode each multi-channel frame into the
//! sequency (Walsh) domain, keep only the coefficients that carry the
//! scene, and triage what is left of the stream:
//!
//! - [`codec`] — the [`CompressedFrame`] wire format: bit-packed sparse
//!   `(index, value)` pairs with per-band quantization, a lossless f32
//!   mode (bit-exact round trip on the sensor grid), a zero-alloc
//!   [`DecodeScratch`] decode that skips fully-dropped channels, and a
//!   versioned byte serialization (`to_bytes`/`from_bytes`) whose
//!   checked decoder maps every malformed input to a [`CodecError`].
//! - [`encoder`] — snap → per-channel sequency FWHT → global top-K /
//!   energy-fraction [`Selection`], with deterministic per-frame-id
//!   dither (`Rng::for_stream`, the serving path's own contract).
//! - [`channel`] — a deterministic fault-injecting link model
//!   ([`Channel`]): seeded bit flips, truncation, duplication,
//!   reordering and drops between encoder and coordinator.
//! - [`retention`] — [`RetentionPolicy`]: keep / summarize / drop,
//!   scored by retained-energy and classifier-margin proxies.
//! - [`stats`] — [`FrontendStats`], merged into the coordinator's
//!   `MetricsSnapshot` next to the PR-2 conversion counters.
//!
//! [`SensorFrontend`] composes the three into the per-stream ingest
//! object `adcim serve --frontend` runs ahead of admission. Kept frames
//! travel the coordinator natively as
//! [`crate::coordinator::FramePayload::Compressed`] and are served
//! either through the engine's exact decode fallback or the
//! sequency-domain folded fast path (`coordinator::engine`).

pub mod channel;
pub mod codec;
pub mod encoder;
pub mod retention;
pub mod stats;

pub use channel::{Channel, ChannelConfig, ChannelStats};
pub use codec::{
    CodecError, CodecParams, CompressedFrame, DecodeScratch, LOSSLESS, WIRE_HEADER_BYTES,
    WIRE_MAGIC, WIRE_VERSION,
};
pub use encoder::{FrameEncoder, Selection};
pub use retention::{FrameSummary, RetentionPolicy, Verdict};
pub use stats::FrontendStats;

/// Frontend configuration: codec geometry + selection + policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrontendConfig {
    /// Codec geometry (channels, samples, bit widths).
    pub params: CodecParams,
    /// Coefficient-selection rule.
    pub selection: Selection,
    /// Keep/summarize/drop triage rule.
    pub policy: RetentionPolicy,
    /// Dither quantized coefficients (deterministic per frame id).
    pub dither: bool,
    /// Seed for the dither stream.
    pub seed: u64,
}

impl FrontendConfig {
    /// A keep-everything frontend over the given geometry.
    pub fn new(params: CodecParams, selection: Selection) -> Self {
        FrontendConfig {
            params,
            selection,
            policy: RetentionPolicy::KeepAll,
            dither: false,
            seed: 0,
        }
    }
}

/// What the frontend hands back per ingested frame.
#[derive(Debug, Clone, PartialEq)]
pub enum IngestDecision {
    /// Forward this compressed frame to serving.
    Keep(CompressedFrame),
    /// Retain only the summary; shed the frame.
    Summarize(FrameSummary),
    /// Shed everything.
    Drop,
}

/// The streaming sensor frontend: one encoder + policy + counters.
#[derive(Debug, Clone)]
pub struct SensorFrontend {
    encoder: FrameEncoder,
    policy: RetentionPolicy,
    stats: FrontendStats,
}

impl SensorFrontend {
    /// Frontend from a validated configuration.
    pub fn new(cfg: FrontendConfig) -> Self {
        let mut encoder = FrameEncoder::new(cfg.params, cfg.selection);
        encoder.dither = cfg.dither;
        encoder.seed = cfg.seed;
        SensorFrontend { encoder, policy: cfg.policy, stats: FrontendStats::default() }
    }

    /// The codec geometry in use.
    pub fn params(&self) -> CodecParams {
        self.encoder.params()
    }

    /// Ingest one dense frame: encode, triage, account.
    pub fn ingest(&mut self, frame: &[f32], frame_id: u64, stream: u32) -> IngestDecision {
        let p = self.encoder.params();
        self.stats.frames_in += 1;
        self.stats.bytes_in += p.raw_frame_bytes() as u64;
        let cf = self.encoder.encode(frame, frame_id);
        self.stats.record_retained(cf.retained_energy);
        match self.policy.decide(&cf) {
            Verdict::Keep => {
                self.stats.kept += 1;
                self.stats.bytes_out += cf.encoded_bytes() as u64;
                IngestDecision::Keep(cf)
            }
            Verdict::Summarize => {
                let summary = FrameSummary::of(frame_id, stream, frame, p.channels);
                self.stats.summarized += 1;
                self.stats.bytes_out += summary.encoded_bytes() as u64;
                IngestDecision::Summarize(summary)
            }
            Verdict::Drop => {
                self.stats.dropped += 1;
                IngestDecision::Drop
            }
        }
    }

    /// Triage counters accumulated so far.
    pub fn stats(&self) -> &FrontendStats {
        &self.stats
    }

    /// Take the accumulated counters, resetting them (delta reporting
    /// into [`crate::coordinator::Metrics`]).
    pub fn take_stats(&mut self) -> FrontendStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg(k: usize) -> FrontendConfig {
        let params = CodecParams::new(1, 64, 8, 8).unwrap();
        FrontendConfig {
            policy: RetentionPolicy::triage_default(),
            ..FrontendConfig::new(params, Selection::TopK(k))
        }
    }

    #[test]
    fn ingest_accounts_every_path() {
        let mut fe = SensorFrontend::new(cfg(8));
        // Structured frame → kept.
        let structured: Vec<f32> =
            (0..64).map(|i| if (i / 4) % 2 == 0 { 0.9 } else { 0.1 }).collect();
        assert!(matches!(fe.ingest(&structured, 0, 0), IngestDecision::Keep(_)));
        // Blank frame → dropped.
        let blank = vec![0.5f32; 64];
        assert!(matches!(fe.ingest(&blank, 1, 0), IngestDecision::Drop));
        let s = fe.stats();
        assert_eq!(s.frames_in, 2);
        assert_eq!(s.kept, 1);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.bytes_in, 2 * 64 * 4);
        assert!(s.bytes_out > 0 && s.bytes_out < s.bytes_in);
    }

    /// Same frames, same ids ⇒ identical decisions, frames and stats —
    /// the frontend is a pure function of the stream (dither included).
    #[test]
    fn frontend_is_deterministic() {
        let mk = || {
            let mut c = cfg(12);
            c.dither = true;
            c.seed = 0xfe;
            SensorFrontend::new(c)
        };
        let mut rng = Rng::new(3);
        let frames: Vec<Vec<f32>> = (0..16)
            .map(|_| (0..64).map(|_| rng.uniform() as f32).collect())
            .collect();
        let mut a = mk();
        let mut b = mk();
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(a.ingest(f, i as u64, 0), b.ingest(f, i as u64, 0), "frame {i}");
        }
        assert_eq!(a.stats(), b.stats());
    }

    #[test]
    fn take_stats_resets() {
        let mut fe = SensorFrontend::new(cfg(8));
        fe.ingest(&vec![0.5f32; 64], 0, 0);
        let taken = fe.take_stats();
        assert_eq!(taken.frames_in, 1);
        assert_eq!(fe.stats().frames_in, 0);
    }
}
