//! The compressed sensor-frame codec (paper §II-A: "selectively retain
//! valuable data from sensors in the frequency domain").
//!
//! A [`CompressedFrame`] is the wire/storage form of one multi-channel
//! sensor frame after the frontend's sequency-domain triage: the kept
//! Walsh–Hadamard coefficients as bit-packed `(index, value)` pairs —
//! `ceil(log2(channels·block))`-bit indices plus either raw f32 bits
//! (lossless mode, `codec_bits == 0`) or offset-binary levels quantized
//! against a per-band scale (`codec_bits` in 2..=16). Per-band scales
//! are stored only for bands that actually hold kept coefficients (a
//! band-occupancy bitmap makes the mapping recoverable), so sparse
//! frames don't pay for empty spectrum.
//!
//! Decoding scatters the kept coefficients straight into *Hadamard*
//! order (one permutation lookup per coefficient, no snapshot buffer)
//! and runs one inverse FWHT per non-empty channel — channels whose
//! coefficients were all dropped skip their transform entirely, which is
//! the codec-level half of the serving fast path. The exactness story:
//! frames are snapped to the sensor's `2^sensor_bits`-step grid at
//! encode, so with every coefficient kept losslessly the decode is
//! **bit-exact** (all butterfly intermediates are grid-unit integers
//! below the f32 exact-integer bound — enforced by
//! [`CodecParams::new`]).
//!
//! **Untrusted ingest**: frames cross node boundaries as the versioned
//! wire format of [`CompressedFrame::to_bytes`] /
//! [`CompressedFrame::from_bytes`] — a magic/version header, explicit
//! field lengths, little-endian throughout. `from_bytes` is *total*
//! over arbitrary bytes: every structural defect maps to a
//! [`CodecError`], every declared length is cross-checked against the
//! bytes actually received before anything is allocated, and a frame it
//! accepts can be decoded by the infallible hot paths without panicking.
//! The checked twins ([`BitReader::try_read`],
//! [`CompressedFrame::try_for_each_coeff`],
//! [`DecodeScratch::try_decode`]) keep hostile frames total end to end;
//! the infallible variants remain for trusted in-process frames.

use crate::wht::fwht::walsh_to_hadamard_index;
use crate::wht::fwht_inplace;

/// `codec_bits` sentinel: store kept coefficients as raw f32 bits.
pub const LOSSLESS: u8 = 0;

/// Bands per channel for the quantizer's scale grouping.
pub const BANDS_PER_CHANNEL: usize = 8;

/// Wire-format magic: "Analog Compressed Frame", version suffix below.
pub const WIRE_MAGIC: [u8; 4] = *b"ACF1";

/// Wire-format version accepted by [`CompressedFrame::from_bytes`].
pub const WIRE_VERSION: u8 = 1;

/// Fixed wire header size: magic (4) + version (1) + sensor bits (1) +
/// codec bits (1) + reserved (1) + channels u16 + samples u16 +
/// kept u32 + frame id u64 + scale count u16 + packed length u32, all
/// little-endian. The encode-time triage scores (`retained_energy` …)
/// are diagnostics, not wire payload.
pub const WIRE_HEADER_BYTES: usize = 30;

/// Hard cap on `channels`, enforced by [`CodecParams::new`]: together
/// with the exactness bound (which caps `block` at 2048) it bounds
/// every decoder-side allocation a hostile wire frame can request —
/// dense output, band bitmap, scale table — and keeps `channels` /
/// `samples` inside their u16 wire fields.
pub const MAX_CHANNELS: usize = 4096;

/// Why a byte stream was rejected by [`CompressedFrame::from_bytes`]
/// (or a frame by the checked decode paths). Every variant is a
/// *rejected input*, never a panic: the decoder is total over
/// arbitrary bytes.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecError {
    /// Stream ends before the bytes the header promises.
    Truncated { need: usize, have: usize },
    /// First four bytes are not [`WIRE_MAGIC`].
    BadMagic,
    /// Unknown wire version.
    BadVersion(u8),
    /// Header geometry rejected by [`CodecParams::new`].
    BadParams(String),
    /// A declared count/length disagrees with what the header implies.
    LengthOverflow { field: &'static str, declared: u64, expected: u64 },
    /// Scale count does not match the band bitmap's population count.
    BandScaleMismatch { declared: usize, expected: usize },
    /// A band scale is NaN or infinite.
    NonFiniteScale { index: usize },
    /// A lossless coefficient value is NaN or infinite.
    NonFiniteValue { index: usize },
    /// A packed coefficient index falls outside the coefficient space.
    IndexOutOfRange { index: usize, space: usize },
    /// Structurally readable but not the canonical encoder output
    /// (non-ascending indices, nonzero padding/reserved bits, trailing
    /// bytes, …) — rejected so every accepted stream has exactly one
    /// decoding and `to_bytes ∘ from_bytes` is the identity.
    NonCanonical(&'static str),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated { need, have } => {
                write!(f, "truncated stream: need {need} bytes, have {have}")
            }
            CodecError::BadMagic => write!(f, "bad magic (not a compressed frame)"),
            CodecError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            CodecError::BadParams(msg) => write!(f, "invalid codec params: {msg}"),
            CodecError::LengthOverflow { field, declared, expected } => {
                write!(f, "declared {field} = {declared}, expected {expected}")
            }
            CodecError::BandScaleMismatch { declared, expected } => {
                write!(f, "scale count {declared} != occupied band count {expected}")
            }
            CodecError::NonFiniteScale { index } => {
                write!(f, "band scale {index} is not finite")
            }
            CodecError::NonFiniteValue { index } => {
                write!(f, "lossless coefficient {index} is not finite")
            }
            CodecError::IndexOutOfRange { index, space } => {
                write!(f, "coefficient index {index} outside space {space}")
            }
            CodecError::NonCanonical(what) => write!(f, "non-canonical encoding: {what}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Geometry + precision of a frame codec. `samples` is the per-channel
/// logical length; each channel transforms in one `block`-sized
/// (next power of two) Walsh–Hadamard block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodecParams {
    /// Spectral channels per frame.
    pub channels: usize,
    /// Samples per channel.
    pub samples: usize,
    /// Sensor grid resolution: inputs snap to multiples of
    /// `2^-sensor_bits` in [0, 1] before the transform (the front ADC).
    pub sensor_bits: u8,
    /// Kept-coefficient precision; [`LOSSLESS`] (0) stores f32 bits.
    pub codec_bits: u8,
}

impl CodecParams {
    /// Validate and build. The `block² · 2^sensor_bits ≤ 2^24` bound is
    /// what makes the lossless round trip bit-exact: every butterfly
    /// intermediate of transform + inverse is an integer multiple of the
    /// sensor grid step no larger than that product, and f32 represents
    /// integers exactly up to 2^24.
    pub fn new(
        channels: usize,
        samples: usize,
        sensor_bits: u8,
        codec_bits: u8,
    ) -> Result<Self, String> {
        if channels == 0 || samples == 0 {
            return Err("codec needs at least one channel and one sample".to_string());
        }
        if channels > MAX_CHANNELS {
            return Err(format!(
                "channels {channels} exceeds the wire cap {MAX_CHANNELS} \
                 (bounds decoder-side allocations for untrusted frames)"
            ));
        }
        if !(1..=12).contains(&sensor_bits) {
            return Err(format!("sensor_bits {sensor_bits} outside 1..=12"));
        }
        if codec_bits != LOSSLESS && !(2..=16).contains(&codec_bits) {
            return Err(format!("codec_bits {codec_bits} outside {{0, 2..=16}}"));
        }
        let block = samples.next_power_of_two();
        let worst = (block as u64) * (block as u64) * (1u64 << sensor_bits);
        if worst > 1 << 24 {
            return Err(format!(
                "block {block} at {sensor_bits} sensor bits exceeds the f32 \
                 exact-integer bound (block^2 * 2^bits = {worst} > 2^24); \
                 shrink the frame or the sensor resolution"
            ));
        }
        Ok(CodecParams { channels, samples, sensor_bits, codec_bits })
    }

    /// Per-channel transform length (next power of two ≥ `samples`).
    #[inline]
    pub fn block(&self) -> usize {
        self.samples.next_power_of_two()
    }

    /// Dense (raw) frame length: `channels · samples`.
    #[inline]
    pub fn dense_len(&self) -> usize {
        self.channels * self.samples
    }

    /// Total coefficient space: `channels · block`.
    #[inline]
    pub fn coeff_space(&self) -> usize {
        self.channels * self.block()
    }

    /// Bits per packed coefficient index.
    #[inline]
    pub fn index_bits(&self) -> u32 {
        let space = self.coeff_space();
        usize::BITS - (space - 1).leading_zeros().min(usize::BITS - 1)
    }

    /// Bits per packed coefficient value.
    #[inline]
    pub fn value_bits(&self) -> u32 {
        if self.codec_bits == LOSSLESS {
            32
        } else {
            self.codec_bits as u32
        }
    }

    /// Scale bands per channel (≤ [`BANDS_PER_CHANNEL`], never wider
    /// than the block).
    #[inline]
    pub fn bands(&self) -> usize {
        BANDS_PER_CHANNEL.min(self.block())
    }

    /// Band of sequency `s` within a channel.
    #[inline]
    pub fn band_of(&self, s: usize) -> usize {
        s * self.bands() / self.block()
    }

    /// Bytes of the uncompressed f32 frame (the ingest-side baseline).
    #[inline]
    pub fn raw_frame_bytes(&self) -> usize {
        self.dense_len() * 4
    }

    /// Snap a sensor value to the `2^-sensor_bits` grid in [0, 1].
    /// Non-finite readings (a faulty sensor) snap to 0 — the encoder
    /// must stay total on real-world input.
    #[inline]
    pub fn snap(&self, v: f32) -> f32 {
        if !v.is_finite() {
            return 0.0;
        }
        let levels = (1u32 << self.sensor_bits) as f32;
        (v.clamp(0.0, 1.0) * levels).round() / levels
    }
}

/// One encoded frame: sparse sequency-domain coefficients plus the
/// encode-time triage scores the retention policy reads. The metric
/// fields (`retained_energy` …) are diagnostics, not wire payload —
/// [`CompressedFrame::encoded_bytes`] excludes them.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedFrame {
    /// Caller-assigned frame identity (becomes the request id).
    pub frame_id: u64,
    /// The codec the frame was encoded under.
    pub params: CodecParams,
    /// Number of packed coefficients.
    pub kept: usize,
    /// Band-occupancy bitmap (`channels · bands` bits, LSB-first);
    /// empty in lossless mode.
    band_map: Vec<u8>,
    /// Per-occupied-band quantizer scales in `(channel, band)` order;
    /// empty in lossless mode.
    scales: Vec<f32>,
    /// Bit-packed `(index, value)` pairs, ascending index.
    packed: Vec<u8>,
    /// Fraction of total coefficient energy kept (1.0 for a silent
    /// frame).
    pub retained_energy: f32,
    /// Fraction of *AC* (sequency ≠ 0) energy kept; 0.0 when the frame
    /// has no AC content.
    pub ac_retained: f32,
    /// Peak |AC coefficient| over mean |AC coefficient| — the
    /// classifier-margin proxy (a confident oriented structure
    /// concentrates in few sequency bins).
    pub peak_to_mean: f32,
    /// Absolute AC coefficient energy, normalised per block
    /// (`Σ_{s≠0} y² / block`): the dead-sensor floor signal.
    pub ac_energy: f32,
}

impl CompressedFrame {
    pub(crate) fn from_parts(
        frame_id: u64,
        params: CodecParams,
        kept: usize,
        band_map: Vec<u8>,
        scales: Vec<f32>,
        packed: Vec<u8>,
    ) -> Self {
        CompressedFrame {
            frame_id,
            params,
            kept,
            band_map,
            scales,
            packed,
            retained_energy: 0.0,
            ac_retained: 0.0,
            peak_to_mean: 0.0,
            ac_energy: 0.0,
        }
    }

    /// Wire size in bytes: header + band bitmap + per-band scales +
    /// packed coefficient pairs. Always equals `to_bytes().len()`.
    pub fn encoded_bytes(&self) -> usize {
        WIRE_HEADER_BYTES + self.band_map.len() + self.scales.len() * 4 + self.packed.len()
    }

    /// Serialize to the versioned wire format (see [`WIRE_HEADER_BYTES`]
    /// for the layout). Infallible: [`CodecParams::new`] caps `channels`
    /// and the exactness bound caps `block`, so every field fits its
    /// wire width.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_bytes());
        out.extend_from_slice(&WIRE_MAGIC);
        out.push(WIRE_VERSION);
        out.push(self.params.sensor_bits);
        out.push(self.params.codec_bits);
        out.push(0); // reserved
        out.extend_from_slice(&(self.params.channels as u16).to_le_bytes());
        out.extend_from_slice(&(self.params.samples as u16).to_le_bytes());
        out.extend_from_slice(&(self.kept as u32).to_le_bytes());
        out.extend_from_slice(&self.frame_id.to_le_bytes());
        out.extend_from_slice(&(self.scales.len() as u16).to_le_bytes());
        out.extend_from_slice(&(self.packed.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.band_map);
        for s in &self.scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out.extend_from_slice(&self.packed);
        out
    }

    /// Parse the wire format. Total over arbitrary bytes — every defect
    /// maps to a [`CodecError`] — and allocation-bounded: declared
    /// lengths are checked against both the header-implied values and
    /// the bytes actually present *before* any buffer is sized from
    /// them. An accepted frame is safe for the infallible decode paths
    /// (the packed stream is fully validated here), and canonical:
    /// `to_bytes(from_bytes(b)?) == b`.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, CodecError> {
        if bytes.len() < 4 {
            return Err(CodecError::Truncated { need: WIRE_HEADER_BYTES, have: bytes.len() });
        }
        if bytes[..4] != WIRE_MAGIC {
            return Err(CodecError::BadMagic);
        }
        if bytes.len() < WIRE_HEADER_BYTES {
            return Err(CodecError::Truncated { need: WIRE_HEADER_BYTES, have: bytes.len() });
        }
        if bytes[4] != WIRE_VERSION {
            return Err(CodecError::BadVersion(bytes[4]));
        }
        let sensor_bits = bytes[5];
        let codec_bits = bytes[6];
        if bytes[7] != 0 {
            return Err(CodecError::NonCanonical("nonzero reserved header byte"));
        }
        let channels = le_u16(bytes, 8) as usize;
        let samples = le_u16(bytes, 10) as usize;
        let kept = le_u32(bytes, 12) as usize;
        let frame_id = le_u64(bytes, 16);
        let n_scales = le_u16(bytes, 24) as usize;
        let packed_len = le_u32(bytes, 26) as usize;

        let params = CodecParams::new(channels, samples, sensor_bits, codec_bits)
            .map_err(CodecError::BadParams)?;
        let space = params.coeff_space();
        if kept > space {
            return Err(CodecError::LengthOverflow {
                field: "kept",
                declared: kept as u64,
                expected: space as u64,
            });
        }
        let lossless = codec_bits == LOSSLESS;
        let n_bands = if lossless { 0 } else { channels * params.bands() };
        let band_map_len = n_bands.div_ceil(8);
        if n_scales > n_bands {
            return Err(CodecError::LengthOverflow {
                field: "scales",
                declared: n_scales as u64,
                expected: n_bands as u64,
            });
        }
        // The packed length is implied by `kept`: reject any other
        // declaration before trusting it for slicing.
        let pair_bits = (params.index_bits() + params.value_bits()) as u64;
        let expected_packed = (kept as u64 * pair_bits).div_ceil(8) as usize;
        if packed_len != expected_packed {
            return Err(CodecError::LengthOverflow {
                field: "packed",
                declared: packed_len as u64,
                expected: expected_packed as u64,
            });
        }
        let need = WIRE_HEADER_BYTES + band_map_len + n_scales * 4 + packed_len;
        if bytes.len() < need {
            return Err(CodecError::Truncated { need, have: bytes.len() });
        }
        if bytes.len() > need {
            return Err(CodecError::NonCanonical("trailing bytes after frame"));
        }

        let band_map = bytes[WIRE_HEADER_BYTES..WIRE_HEADER_BYTES + band_map_len].to_vec();
        let mut at = WIRE_HEADER_BYTES + band_map_len;
        let mut scales = Vec::with_capacity(n_scales);
        for i in 0..n_scales {
            let s = f32::from_le_bytes([bytes[at], bytes[at + 1], bytes[at + 2], bytes[at + 3]]);
            if !s.is_finite() {
                return Err(CodecError::NonFiniteScale { index: i });
            }
            if s < 0.0 {
                return Err(CodecError::NonCanonical("negative band scale"));
            }
            scales.push(s);
            at += 4;
        }
        let packed = bytes[at..at + packed_len].to_vec();

        if !lossless {
            // Bitmap invariants: padding bits clear, population count
            // equal to the scale table length.
            let mut pop = 0usize;
            for bit in 0..band_map_len * 8 {
                if band_map_get(&band_map, bit) {
                    if bit >= n_bands {
                        return Err(CodecError::NonCanonical("band bitmap padding not zero"));
                    }
                    pop += 1;
                }
            }
            if pop != n_scales {
                return Err(CodecError::BandScaleMismatch { declared: n_scales, expected: pop });
            }
        }

        let frame = CompressedFrame::from_parts(frame_id, params, kept, band_map, scales, packed);
        frame.validate_packed()?;
        Ok(frame)
    }

    /// Full scan of the packed pair stream: every index in range and
    /// strictly ascending, lossy coefficients only in occupied bands,
    /// lossless values finite, final-byte padding zero. After this
    /// passes, the infallible decode paths cannot fail on the frame.
    fn validate_packed(&self) -> Result<(), CodecError> {
        let p = self.params;
        let idx_bits = p.index_bits();
        let val_bits = p.value_bits();
        let space = p.coeff_space();
        let block = p.block();
        let lossless = p.codec_bits == LOSSLESS;
        let exhausted =
            CodecError::Truncated { need: self.packed.len() + 1, have: self.packed.len() };
        let mut reader = BitReader::new(&self.packed);
        let mut last: Option<usize> = None;
        for k in 0..self.kept {
            let idx = reader.try_read(idx_bits).ok_or(exhausted.clone())? as usize;
            if idx >= space {
                return Err(CodecError::IndexOutOfRange { index: idx, space });
            }
            if last.is_some_and(|l| idx <= l) {
                return Err(CodecError::NonCanonical("coefficient indices must strictly ascend"));
            }
            last = Some(idx);
            let raw = reader.try_read(val_bits).ok_or(exhausted.clone())?;
            if lossless {
                if !f32::from_bits(raw as u32).is_finite() {
                    return Err(CodecError::NonFiniteValue { index: k });
                }
            } else {
                let (ch, s) = (idx / block, idx % block);
                if !band_map_get(&self.band_map, ch * p.bands() + p.band_of(s)) {
                    return Err(CodecError::NonCanonical("kept coefficient in unoccupied band"));
                }
            }
        }
        // `packed.len()` was matched against ceil(kept·pair_bits/8), so
        // fewer than 8 bits remain; they must be zero for canonicality.
        let left = reader.remaining_bits();
        if left > 0 && reader.try_read(left as u32) != Some(0) {
            return Err(CodecError::NonCanonical("nonzero padding bits in packed stream"));
        }
        Ok(())
    }

    /// Visit every kept coefficient as `(channel, sequency, value)` in
    /// ascending index order, dequantizing against the band scales.
    /// This is the serving hot loop (decode fallback *and* folded fast
    /// path both stand on it): the bitmap → scale rank table is built
    /// once per call, so each coefficient costs O(1).
    pub fn for_each_coeff(&self, mut f: impl FnMut(usize, usize, f32)) {
        let block = self.params.block();
        let idx_bits = self.params.index_bits();
        let val_bits = self.params.value_bits();
        let lossless = self.params.codec_bits == LOSSLESS;
        let max_level = if lossless { 0 } else { (1i64 << (self.params.codec_bits - 1)) - 1 };
        // Occupied-band rank table (same prefix-count rule the encoder
        // packs with); tiny — channels · bands entries.
        let mut scale_of = Vec::new();
        if !lossless {
            let n_bands = self.params.channels * self.params.bands();
            scale_of.resize(n_bands, 0.0f32);
            let mut rank = 0usize;
            for (flat, slot) in scale_of.iter_mut().enumerate() {
                if band_map_get(&self.band_map, flat) {
                    *slot = self.scales[rank];
                    rank += 1;
                }
            }
        }
        let mut reader = BitReader::new(&self.packed);
        for _ in 0..self.kept {
            let idx = reader.read(idx_bits) as usize;
            let (ch, s) = (idx / block, idx % block);
            let v = if lossless {
                f32::from_bits(reader.read(32) as u32)
            } else {
                let stored = reader.read(val_bits) as i64;
                let level = stored - max_level;
                let scale = scale_of[ch * self.params.bands() + self.params.band_of(s)];
                level as f32 * scale / max_level as f32
            };
            f(ch, s, v);
        }
    }

    /// Checked twin of [`Self::for_each_coeff`] for frames that did not
    /// come from this process's encoder: every bit read is
    /// bounds-checked and every index validated, so a corrupt frame
    /// yields a [`CodecError`] instead of a panic. The closure itself
    /// is infallible — validation lives here.
    pub fn try_for_each_coeff(
        &self,
        mut f: impl FnMut(usize, usize, f32),
    ) -> Result<(), CodecError> {
        let p = self.params;
        let block = p.block();
        let idx_bits = p.index_bits();
        let val_bits = p.value_bits();
        let space = p.coeff_space();
        let lossless = p.codec_bits == LOSSLESS;
        let max_level = if lossless { 0 } else { (1i64 << (p.codec_bits - 1)) - 1 };
        let mut scale_of = Vec::new();
        if !lossless {
            let n_bands = p.channels * p.bands();
            if self.band_map.len() * 8 < n_bands {
                return Err(CodecError::Truncated {
                    need: n_bands.div_ceil(8),
                    have: self.band_map.len(),
                });
            }
            let pop = self.band_map.iter().map(|b| b.count_ones() as usize).sum::<usize>();
            if pop != self.scales.len() {
                return Err(CodecError::BandScaleMismatch {
                    declared: self.scales.len(),
                    expected: pop,
                });
            }
            scale_of.resize(n_bands, 0.0f32);
            let mut rank = 0usize;
            for (flat, slot) in scale_of.iter_mut().enumerate() {
                if band_map_get(&self.band_map, flat) {
                    *slot = self.scales[rank];
                    rank += 1;
                }
            }
        }
        let exhausted =
            CodecError::Truncated { need: self.packed.len() + 1, have: self.packed.len() };
        let mut reader = BitReader::new(&self.packed);
        for _ in 0..self.kept {
            let idx = reader.try_read(idx_bits).ok_or(exhausted.clone())? as usize;
            if idx >= space {
                return Err(CodecError::IndexOutOfRange { index: idx, space });
            }
            let (ch, s) = (idx / block, idx % block);
            let v = if lossless {
                f32::from_bits(reader.try_read(32).ok_or(exhausted.clone())? as u32)
            } else {
                let stored = reader.try_read(val_bits).ok_or(exhausted.clone())? as i64;
                let level = stored - max_level;
                let scale = scale_of[ch * p.bands() + p.band_of(s)];
                level as f32 * scale / max_level as f32
            };
            f(ch, s, v);
        }
        Ok(())
    }

    /// Decode into a fresh dense frame (reference path; allocation-free
    /// serving uses [`DecodeScratch::decode`]).
    pub fn decode(&self) -> Vec<f32> {
        let mut scratch = DecodeScratch::default();
        scratch.decode(self).to_vec()
    }

    /// Fallible [`Self::decode`] for frames from untrusted sources.
    pub fn try_decode(&self) -> Result<Vec<f32>, CodecError> {
        let mut scratch = DecodeScratch::default();
        scratch.try_decode(self).map(<[f32]>::to_vec)
    }
}

fn le_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn le_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn le_u64(b: &[u8], at: usize) -> u64 {
    let mut w = [0u8; 8];
    w.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(w)
}

#[inline]
fn band_map_get(map: &[u8], bit: usize) -> bool {
    map[bit / 8] & (1 << (bit % 8)) != 0
}

#[inline]
pub(crate) fn band_map_set(map: &mut [u8], bit: usize) {
    map[bit / 8] |= 1 << (bit % 8);
}

/// Reusable decode buffers: the dense output frame plus one
/// Hadamard-order block. Kept per serving worker so the frame-sized
/// buffers are reused across decodes instead of reallocated.
#[derive(Debug, Default, Clone)]
pub struct DecodeScratch {
    dense: Vec<f32>,
    block: Vec<f32>,
}

impl DecodeScratch {
    /// Decode `frame` into the internal dense buffer and return it
    /// (trusted in-process frames; panics on a corrupt one).
    pub fn decode(&mut self, frame: &CompressedFrame) -> &[f32] {
        self.try_decode(frame).expect("corrupt CompressedFrame on the trusted decode path")
    }

    /// Decode `frame` into the internal dense buffer and return it,
    /// reporting a [`CodecError`] instead of panicking when the frame
    /// is corrupt (the untrusted-ingest path).
    ///
    /// Coefficients scatter directly into Hadamard order (one
    /// permutation lookup each), then each **non-empty** channel runs
    /// one inverse FWHT — fully-dropped channels skip the transform and
    /// stay zero.
    pub fn try_decode(&mut self, frame: &CompressedFrame) -> Result<&[f32], CodecError> {
        let p = frame.params;
        let block = p.block();
        let bits = block.trailing_zeros();
        self.dense.clear();
        self.dense.resize(p.dense_len(), 0.0);
        self.block.clear();
        self.block.resize(block, 0.0);

        // Kept pairs arrive in ascending index order, so each channel's
        // coefficients are contiguous: flush a channel when the next
        // pair belongs to a later one.
        let mut open: Option<usize> = None;
        let dense = &mut self.dense;
        let blk = &mut self.block;
        let mut flush = |ch: usize, buf: &mut Vec<f32>| {
            fwht_inplace(buf);
            let inv = 1.0 / block as f32;
            let out = &mut dense[ch * p.samples..(ch + 1) * p.samples];
            for (o, v) in out.iter_mut().zip(buf.iter()) {
                *o = v * inv;
            }
            buf.iter_mut().for_each(|v| *v = 0.0);
        };
        frame.try_for_each_coeff(|ch, s, v| {
            if let Some(cur) = open {
                if cur != ch {
                    flush(cur, &mut *blk);
                    open = Some(ch);
                }
            } else {
                open = Some(ch);
            }
            blk[walsh_to_hadamard_index(s, bits)] = v;
        })?;
        if let Some(cur) = open {
            flush(cur, &mut *blk);
        }
        Ok(&self.dense)
    }
}

// ------------------------------------------------------------ bit I/O

/// LSB-first bit packer.
#[derive(Debug, Default)]
pub(crate) struct BitWriter {
    bytes: Vec<u8>,
    /// Bits already used in the trailing byte (0 = byte-aligned).
    used: u32,
}

impl BitWriter {
    /// Append the low `bits` of `value`, LSB first.
    pub fn push(&mut self, value: u64, bits: u32) {
        debug_assert!(bits <= 64);
        debug_assert!(bits == 64 || value < (1u64 << bits));
        let mut v = value;
        let mut left = bits;
        while left > 0 {
            if self.used == 0 {
                self.bytes.push(0);
            }
            let slot = 8 - self.used;
            let take = slot.min(left);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            *self.bytes.last_mut().unwrap() |= ((v & mask) as u8) << self.used;
            self.used = (self.used + take) % 8;
            v >>= take;
            left -= take;
        }
    }

    /// Finish and take the packed bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// LSB-first bit reader (mirror of [`BitWriter`]).
pub(crate) struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    /// Reader over packed bytes, starting at bit 0.
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    /// Bits left to read.
    pub fn remaining_bits(&self) -> usize {
        self.bytes.len() * 8 - self.pos
    }

    /// Checked read for untrusted buffers: `None` when fewer than
    /// `bits` remain; nothing is consumed on failure.
    pub fn try_read(&mut self, bits: u32) -> Option<u64> {
        debug_assert!(bits <= 64);
        if self.remaining_bits() < bits as usize {
            return None;
        }
        let mut out = 0u64;
        let mut got = 0u32;
        while got < bits {
            let byte = self.bytes[self.pos / 8];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(bits - got);
            let mask = ((1u16 << take) - 1) as u8;
            out |= (((byte >> off) & mask) as u64) << got;
            self.pos += take as usize;
            got += take;
        }
        Some(out)
    }

    /// Infallible read for trusted in-process buffers (the encoder's
    /// own output). Over-reading is a caller bug: debug builds assert
    /// on the remaining bits, and release builds panic cleanly through
    /// the checked path instead of indexing out of bounds.
    pub fn read(&mut self, bits: u32) -> u64 {
        debug_assert!(
            self.remaining_bits() >= bits as usize,
            "BitReader over-read: {bits} bits requested, {} remain",
            self.remaining_bits()
        );
        self.try_read(bits).expect("BitReader over-read on a trusted buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::encoder::{FrameEncoder, Selection};

    fn enc(ch: usize, samples: usize, codec_bits: u8, sel: Selection, seed: u64) -> CompressedFrame {
        let p = CodecParams::new(ch, samples, 8, codec_bits).unwrap();
        let mut rng = crate::util::Rng::new(seed);
        let frame: Vec<f32> = (0..p.dense_len()).map(|_| rng.uniform() as f32).collect();
        FrameEncoder::new(p, sel).encode(&frame, seed)
    }

    #[test]
    fn try_read_checks_remaining_bits() {
        let bytes = [0xA5u8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.remaining_bits(), 8);
        assert_eq!(r.try_read(5), Some(0b00101));
        assert_eq!(r.try_read(4), None, "only 3 bits remain");
        assert_eq!(r.remaining_bits(), 3, "a failed read consumes nothing");
        assert_eq!(r.try_read(3), Some(0b101));
        assert_eq!(r.try_read(1), None);
    }

    #[test]
    #[should_panic(expected = "over-read")]
    fn trusted_read_past_end_panics_cleanly() {
        let bytes = [0u8];
        let mut r = BitReader::new(&bytes);
        let _ = r.read(9);
    }

    #[test]
    fn wire_round_trip_is_identity_and_canonical() {
        for (ch, samples, bits, sel) in [
            (4usize, 64usize, 8u8, Selection::TopK(16)),
            (3, 33, LOSSLESS, Selection::All),
            (1, 1, 2, Selection::All),
            (2, 256, 6, Selection::EnergyFrac(0.9)),
        ] {
            let f = enc(ch, samples, bits, sel, 7);
            let b = f.to_bytes();
            assert_eq!(b.len(), f.encoded_bytes(), "encoded_bytes must match the wire");
            let g = CompressedFrame::from_bytes(&b).unwrap();
            // The triage scores are diagnostics, not wire payload.
            let mut want = f.clone();
            want.retained_energy = 0.0;
            want.ac_retained = 0.0;
            want.peak_to_mean = 0.0;
            want.ac_energy = 0.0;
            assert_eq!(g, want, "ch={ch} samples={samples} bits={bits}");
            assert_eq!(g.to_bytes(), b, "accepted frames re-encode canonically");
            assert_eq!(g.try_decode().unwrap(), f.decode());
        }
    }

    #[test]
    fn from_bytes_rejects_each_header_corruption() {
        let f = enc(4, 64, 8, Selection::TopK(16), 11);
        let b = f.to_bytes();

        assert_eq!(
            CompressedFrame::from_bytes(&[]),
            Err(CodecError::Truncated { need: WIRE_HEADER_BYTES, have: 0 })
        );
        let mut m = b.clone();
        m[0] ^= 0xff;
        assert_eq!(CompressedFrame::from_bytes(&m), Err(CodecError::BadMagic));
        assert!(matches!(
            CompressedFrame::from_bytes(&b[..10]),
            Err(CodecError::Truncated { need: WIRE_HEADER_BYTES, have: 10 })
        ));
        let mut m = b.clone();
        m[4] = 9;
        assert_eq!(CompressedFrame::from_bytes(&m), Err(CodecError::BadVersion(9)));
        let mut m = b.clone();
        m[7] = 1;
        assert!(matches!(CompressedFrame::from_bytes(&m), Err(CodecError::NonCanonical(_))));
        let mut m = b.clone();
        m[5] = 0; // sensor_bits outside 1..=12
        assert!(matches!(CompressedFrame::from_bytes(&m), Err(CodecError::BadParams(_))));
        let mut m = b.clone();
        m[12..16].copy_from_slice(&u32::MAX.to_le_bytes()); // kept
        assert!(matches!(
            CompressedFrame::from_bytes(&m),
            Err(CodecError::LengthOverflow { field: "kept", .. })
        ));
        let mut m = b.clone();
        m[26] ^= 1; // declared packed length
        assert!(matches!(
            CompressedFrame::from_bytes(&m),
            Err(CodecError::LengthOverflow { field: "packed", .. })
        ));
        let mut m = b.clone();
        m.pop();
        assert!(matches!(CompressedFrame::from_bytes(&m), Err(CodecError::Truncated { .. })));
        let mut m = b.clone();
        m.push(0);
        assert_eq!(
            CompressedFrame::from_bytes(&m),
            Err(CodecError::NonCanonical("trailing bytes after frame"))
        );
    }

    #[test]
    fn from_bytes_rejects_band_scale_corruption() {
        let f = enc(4, 64, 8, Selection::TopK(4), 13);
        let b = f.to_bytes();
        let n_bands = 4 * 8; // channels · bands, exactly 4 bitmap bytes
        let map_off = WIRE_HEADER_BYTES;

        // Set a previously-clear band bit: the bitmap population no
        // longer matches the scale count.
        let mut m = b.clone();
        let bit = (0..n_bands)
            .find(|bit| m[map_off + bit / 8] & (1 << (bit % 8)) == 0)
            .expect("TopK(4) cannot occupy all 32 bands");
        m[map_off + bit / 8] |= 1 << (bit % 8);
        assert!(matches!(
            CompressedFrame::from_bytes(&m),
            Err(CodecError::BandScaleMismatch { .. })
        ));

        // NaN band scale.
        let mut m = b.clone();
        let scale_off = map_off + n_bands / 8;
        m[scale_off..scale_off + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        assert_eq!(CompressedFrame::from_bytes(&m), Err(CodecError::NonFiniteScale { index: 0 }));
    }

    #[test]
    fn packed_stream_corruption_is_rejected() {
        // (3, 33): block 64, coefficient space 192, 8-bit indices —
        // values 192..=255 are representable but out of range.
        let p = CodecParams::new(3, 33, 8, LOSSLESS).unwrap();
        let mut w = BitWriter::default();
        w.push(200, 8);
        w.push(0.5f32.to_bits() as u64, 32);
        let f = CompressedFrame::from_parts(1, p, 1, Vec::new(), Vec::new(), w.into_bytes());
        assert_eq!(
            CompressedFrame::from_bytes(&f.to_bytes()),
            Err(CodecError::IndexOutOfRange { index: 200, space: 192 })
        );
        assert_eq!(f.try_decode(), Err(CodecError::IndexOutOfRange { index: 200, space: 192 }));

        let mut w = BitWriter::default();
        w.push(3, 8);
        w.push(f32::NAN.to_bits() as u64, 32);
        let f = CompressedFrame::from_parts(1, p, 1, Vec::new(), Vec::new(), w.into_bytes());
        assert_eq!(
            CompressedFrame::from_bytes(&f.to_bytes()),
            Err(CodecError::NonFiniteValue { index: 0 })
        );

        let mut w = BitWriter::default();
        for idx in [5u64, 3] {
            w.push(idx, 8);
            w.push(0.5f32.to_bits() as u64, 32);
        }
        let f = CompressedFrame::from_parts(1, p, 2, Vec::new(), Vec::new(), w.into_bytes());
        assert!(matches!(
            CompressedFrame::from_bytes(&f.to_bytes()),
            Err(CodecError::NonCanonical("coefficient indices must strictly ascend"))
        ));

        // A frame claiming more pairs than its packed bytes hold must
        // fail the checked decode instead of panicking.
        let f = CompressedFrame::from_parts(1, p, 5, Vec::new(), Vec::new(), Vec::new());
        assert!(matches!(f.try_decode(), Err(CodecError::Truncated { .. })));
    }

    #[test]
    fn nonzero_packed_padding_is_rejected() {
        // 18-bit pairs (10-bit index + 8-bit level), kept = 3 → 54 bits
        // in 7 bytes: the top two bits of the last byte are padding.
        let f = enc(4, 144, 8, Selection::TopK(3), 17);
        assert_eq!(f.kept, 3);
        let mut b = f.to_bytes();
        let last = b.len() - 1;
        b[last] |= 0x80;
        assert_eq!(
            CompressedFrame::from_bytes(&b),
            Err(CodecError::NonCanonical("nonzero padding bits in packed stream"))
        );
    }

    #[test]
    fn params_reject_channel_cap() {
        assert!(CodecParams::new(MAX_CHANNELS, 4, 8, 8).is_ok());
        let err = CodecParams::new(MAX_CHANNELS + 1, 4, 8, 8).unwrap_err();
        assert!(err.contains("wire cap"), "got: {err}");
    }

    #[test]
    fn bit_io_round_trips_mixed_widths() {
        let widths = [1u32, 3, 7, 8, 9, 13, 16, 24, 32];
        let mut w = BitWriter::default();
        for (i, &bits) in widths.iter().enumerate() {
            let v = (0x9e37_79b9u64.wrapping_mul(i as u64 + 1)) & ((1u64 << bits) - 1);
            w.push(v, bits);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (i, &bits) in widths.iter().enumerate() {
            let want = (0x9e37_79b9u64.wrapping_mul(i as u64 + 1)) & ((1u64 << bits) - 1);
            assert_eq!(r.read(bits), want, "field {i} ({bits} bits)");
        }
    }

    #[test]
    fn params_reject_bad_geometry() {
        assert!(CodecParams::new(0, 4, 8, 8).is_err());
        assert!(CodecParams::new(1, 0, 8, 8).is_err());
        assert!(CodecParams::new(1, 4, 0, 8).is_err());
        assert!(CodecParams::new(1, 4, 8, 1).is_err());
        assert!(CodecParams::new(1, 4, 8, 17).is_err());
        // 1024-block at 8 sensor bits breaks the exact-integer bound.
        assert!(CodecParams::new(1, 1024, 8, 8).is_err());
        assert!(CodecParams::new(1, 1024, 4, 8).is_ok());
        assert!(CodecParams::new(1, 256, 8, LOSSLESS).is_ok());
    }

    #[test]
    fn params_arithmetic() {
        let p = CodecParams::new(4, 144, 8, 8).unwrap();
        assert_eq!(p.block(), 256);
        assert_eq!(p.dense_len(), 576);
        assert_eq!(p.coeff_space(), 1024);
        assert_eq!(p.index_bits(), 10);
        assert_eq!(p.value_bits(), 8);
        assert_eq!(p.bands(), 8);
        assert_eq!(p.band_of(0), 0);
        assert_eq!(p.band_of(255), 7);
        let q = CodecParams::new(1, 3, 8, LOSSLESS).unwrap();
        assert_eq!(q.block(), 4);
        assert_eq!(q.bands(), 4);
        assert_eq!(q.value_bits(), 32);
        assert_eq!(q.index_bits(), 2);
    }

    #[test]
    fn snap_is_idempotent_on_grid() {
        let p = CodecParams::new(1, 8, 4, 8).unwrap();
        for k in 0..=16u32 {
            let v = k as f32 / 16.0;
            assert_eq!(p.snap(v), v, "grid value must be a fixed point");
            assert_eq!(p.snap(p.snap(0.123_456)), p.snap(0.123_456));
        }
        assert_eq!(p.snap(-3.0), 0.0);
        assert_eq!(p.snap(7.0), 1.0);
    }
}
