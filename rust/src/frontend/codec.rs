//! The compressed sensor-frame codec (paper §II-A: "selectively retain
//! valuable data from sensors in the frequency domain").
//!
//! A [`CompressedFrame`] is the wire/storage form of one multi-channel
//! sensor frame after the frontend's sequency-domain triage: the kept
//! Walsh–Hadamard coefficients as bit-packed `(index, value)` pairs —
//! `ceil(log2(channels·block))`-bit indices plus either raw f32 bits
//! (lossless mode, `codec_bits == 0`) or offset-binary levels quantized
//! against a per-band scale (`codec_bits` in 2..=16). Per-band scales
//! are stored only for bands that actually hold kept coefficients (a
//! band-occupancy bitmap makes the mapping recoverable), so sparse
//! frames don't pay for empty spectrum.
//!
//! Decoding scatters the kept coefficients straight into *Hadamard*
//! order (one permutation lookup per coefficient, no snapshot buffer)
//! and runs one inverse FWHT per non-empty channel — channels whose
//! coefficients were all dropped skip their transform entirely, which is
//! the codec-level half of the serving fast path. The exactness story:
//! frames are snapped to the sensor's `2^sensor_bits`-step grid at
//! encode, so with every coefficient kept losslessly the decode is
//! **bit-exact** (all butterfly intermediates are grid-unit integers
//! below the f32 exact-integer bound — enforced by
//! [`CodecParams::new`]).

use crate::wht::fwht::walsh_to_hadamard_index;
use crate::wht::fwht_inplace;

/// `codec_bits` sentinel: store kept coefficients as raw f32 bits.
pub const LOSSLESS: u8 = 0;

/// Bands per channel for the quantizer's scale grouping.
pub const BANDS_PER_CHANNEL: usize = 8;

/// Fixed per-frame header cost charged by [`CompressedFrame::encoded_bytes`]:
/// frame id (8) + channels (2) + samples (4) + sensor/codec bits (2) +
/// kept count (4).
pub const HEADER_BYTES: usize = 20;

/// Geometry + precision of a frame codec. `samples` is the per-channel
/// logical length; each channel transforms in one `block`-sized
/// (next power of two) Walsh–Hadamard block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodecParams {
    pub channels: usize,
    pub samples: usize,
    /// Sensor grid resolution: inputs snap to multiples of
    /// `2^-sensor_bits` in [0, 1] before the transform (the front ADC).
    pub sensor_bits: u8,
    /// Kept-coefficient precision; [`LOSSLESS`] (0) stores f32 bits.
    pub codec_bits: u8,
}

impl CodecParams {
    /// Validate and build. The `block² · 2^sensor_bits ≤ 2^24` bound is
    /// what makes the lossless round trip bit-exact: every butterfly
    /// intermediate of transform + inverse is an integer multiple of the
    /// sensor grid step no larger than that product, and f32 represents
    /// integers exactly up to 2^24.
    pub fn new(
        channels: usize,
        samples: usize,
        sensor_bits: u8,
        codec_bits: u8,
    ) -> Result<Self, String> {
        if channels == 0 || samples == 0 {
            return Err("codec needs at least one channel and one sample".to_string());
        }
        if !(1..=12).contains(&sensor_bits) {
            return Err(format!("sensor_bits {sensor_bits} outside 1..=12"));
        }
        if codec_bits != LOSSLESS && !(2..=16).contains(&codec_bits) {
            return Err(format!("codec_bits {codec_bits} outside {{0, 2..=16}}"));
        }
        let block = samples.next_power_of_two();
        let worst = (block as u64) * (block as u64) * (1u64 << sensor_bits);
        if worst > 1 << 24 {
            return Err(format!(
                "block {block} at {sensor_bits} sensor bits exceeds the f32 \
                 exact-integer bound (block^2 * 2^bits = {worst} > 2^24); \
                 shrink the frame or the sensor resolution"
            ));
        }
        Ok(CodecParams { channels, samples, sensor_bits, codec_bits })
    }

    /// Per-channel transform length (next power of two ≥ `samples`).
    #[inline]
    pub fn block(&self) -> usize {
        self.samples.next_power_of_two()
    }

    /// Dense (raw) frame length: `channels · samples`.
    #[inline]
    pub fn dense_len(&self) -> usize {
        self.channels * self.samples
    }

    /// Total coefficient space: `channels · block`.
    #[inline]
    pub fn coeff_space(&self) -> usize {
        self.channels * self.block()
    }

    /// Bits per packed coefficient index.
    #[inline]
    pub fn index_bits(&self) -> u32 {
        let space = self.coeff_space();
        usize::BITS - (space - 1).leading_zeros().min(usize::BITS - 1)
    }

    /// Bits per packed coefficient value.
    #[inline]
    pub fn value_bits(&self) -> u32 {
        if self.codec_bits == LOSSLESS {
            32
        } else {
            self.codec_bits as u32
        }
    }

    /// Scale bands per channel (≤ [`BANDS_PER_CHANNEL`], never wider
    /// than the block).
    #[inline]
    pub fn bands(&self) -> usize {
        BANDS_PER_CHANNEL.min(self.block())
    }

    /// Band of sequency `s` within a channel.
    #[inline]
    pub fn band_of(&self, s: usize) -> usize {
        s * self.bands() / self.block()
    }

    /// Bytes of the uncompressed f32 frame (the ingest-side baseline).
    #[inline]
    pub fn raw_frame_bytes(&self) -> usize {
        self.dense_len() * 4
    }

    /// Snap a sensor value to the `2^-sensor_bits` grid in [0, 1].
    /// Non-finite readings (a faulty sensor) snap to 0 — the encoder
    /// must stay total on real-world input.
    #[inline]
    pub fn snap(&self, v: f32) -> f32 {
        if !v.is_finite() {
            return 0.0;
        }
        let levels = (1u32 << self.sensor_bits) as f32;
        (v.clamp(0.0, 1.0) * levels).round() / levels
    }
}

/// One encoded frame: sparse sequency-domain coefficients plus the
/// encode-time triage scores the retention policy reads. The metric
/// fields (`retained_energy` …) are diagnostics, not wire payload —
/// [`CompressedFrame::encoded_bytes`] excludes them.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedFrame {
    pub frame_id: u64,
    pub params: CodecParams,
    /// Number of packed coefficients.
    pub kept: usize,
    /// Band-occupancy bitmap (`channels · bands` bits, LSB-first);
    /// empty in lossless mode.
    band_map: Vec<u8>,
    /// Per-occupied-band quantizer scales in `(channel, band)` order;
    /// empty in lossless mode.
    scales: Vec<f32>,
    /// Bit-packed `(index, value)` pairs, ascending index.
    packed: Vec<u8>,
    /// Fraction of total coefficient energy kept (1.0 for a silent
    /// frame).
    pub retained_energy: f32,
    /// Fraction of *AC* (sequency ≠ 0) energy kept; 0.0 when the frame
    /// has no AC content.
    pub ac_retained: f32,
    /// Peak |AC coefficient| over mean |AC coefficient| — the
    /// classifier-margin proxy (a confident oriented structure
    /// concentrates in few sequency bins).
    pub peak_to_mean: f32,
    /// Absolute AC coefficient energy, normalised per block
    /// (`Σ_{s≠0} y² / block`): the dead-sensor floor signal.
    pub ac_energy: f32,
}

impl CompressedFrame {
    pub(crate) fn from_parts(
        frame_id: u64,
        params: CodecParams,
        kept: usize,
        band_map: Vec<u8>,
        scales: Vec<f32>,
        packed: Vec<u8>,
    ) -> Self {
        CompressedFrame {
            frame_id,
            params,
            kept,
            band_map,
            scales,
            packed,
            retained_energy: 0.0,
            ac_retained: 0.0,
            peak_to_mean: 0.0,
            ac_energy: 0.0,
        }
    }

    /// Wire size in bytes: header + band bitmap + per-band scales +
    /// packed coefficient pairs.
    pub fn encoded_bytes(&self) -> usize {
        HEADER_BYTES + self.band_map.len() + self.scales.len() * 4 + self.packed.len()
    }

    /// Visit every kept coefficient as `(channel, sequency, value)` in
    /// ascending index order, dequantizing against the band scales.
    /// This is the serving hot loop (decode fallback *and* folded fast
    /// path both stand on it): the bitmap → scale rank table is built
    /// once per call, so each coefficient costs O(1).
    pub fn for_each_coeff(&self, mut f: impl FnMut(usize, usize, f32)) {
        let block = self.params.block();
        let idx_bits = self.params.index_bits();
        let val_bits = self.params.value_bits();
        let lossless = self.params.codec_bits == LOSSLESS;
        let max_level = if lossless { 0 } else { (1i64 << (self.params.codec_bits - 1)) - 1 };
        // Occupied-band rank table (same prefix-count rule the encoder
        // packs with); tiny — channels · bands entries.
        let mut scale_of = Vec::new();
        if !lossless {
            let n_bands = self.params.channels * self.params.bands();
            scale_of.resize(n_bands, 0.0f32);
            let mut rank = 0usize;
            for (flat, slot) in scale_of.iter_mut().enumerate() {
                if band_map_get(&self.band_map, flat) {
                    *slot = self.scales[rank];
                    rank += 1;
                }
            }
        }
        let mut reader = BitReader::new(&self.packed);
        for _ in 0..self.kept {
            let idx = reader.read(idx_bits) as usize;
            let (ch, s) = (idx / block, idx % block);
            let v = if lossless {
                f32::from_bits(reader.read(32) as u32)
            } else {
                let stored = reader.read(val_bits) as i64;
                let level = stored - max_level;
                let scale = scale_of[ch * self.params.bands() + self.params.band_of(s)];
                level as f32 * scale / max_level as f32
            };
            f(ch, s, v);
        }
    }

    /// Decode into a fresh dense frame (reference path; allocation-free
    /// serving uses [`DecodeScratch::decode`]).
    pub fn decode(&self) -> Vec<f32> {
        let mut scratch = DecodeScratch::default();
        scratch.decode(self).to_vec()
    }
}

#[inline]
fn band_map_get(map: &[u8], bit: usize) -> bool {
    map[bit / 8] & (1 << (bit % 8)) != 0
}

#[inline]
pub(crate) fn band_map_set(map: &mut [u8], bit: usize) {
    map[bit / 8] |= 1 << (bit % 8);
}

/// Reusable decode buffers: the dense output frame plus one
/// Hadamard-order block. Kept per serving worker so the frame-sized
/// buffers are reused across decodes instead of reallocated.
#[derive(Debug, Default, Clone)]
pub struct DecodeScratch {
    dense: Vec<f32>,
    block: Vec<f32>,
}

impl DecodeScratch {
    /// Decode `frame` into the internal dense buffer and return it.
    ///
    /// Coefficients scatter directly into Hadamard order (one
    /// permutation lookup each), then each **non-empty** channel runs
    /// one inverse FWHT — fully-dropped channels skip the transform and
    /// stay zero.
    pub fn decode(&mut self, frame: &CompressedFrame) -> &[f32] {
        let p = frame.params;
        let block = p.block();
        let bits = block.trailing_zeros();
        self.dense.clear();
        self.dense.resize(p.dense_len(), 0.0);
        self.block.clear();
        self.block.resize(block, 0.0);

        // Kept pairs arrive in ascending index order, so each channel's
        // coefficients are contiguous: flush a channel when the next
        // pair belongs to a later one.
        let mut open: Option<usize> = None;
        let dense = &mut self.dense;
        let blk = &mut self.block;
        let mut flush = |ch: usize, buf: &mut Vec<f32>| {
            fwht_inplace(buf);
            let inv = 1.0 / block as f32;
            let out = &mut dense[ch * p.samples..(ch + 1) * p.samples];
            for (o, v) in out.iter_mut().zip(buf.iter()) {
                *o = v * inv;
            }
            buf.iter_mut().for_each(|v| *v = 0.0);
        };
        frame.for_each_coeff(|ch, s, v| {
            if let Some(cur) = open {
                if cur != ch {
                    flush(cur, &mut *blk);
                    open = Some(ch);
                }
            } else {
                open = Some(ch);
            }
            blk[walsh_to_hadamard_index(s, bits)] = v;
        });
        if let Some(cur) = open {
            flush(cur, &mut *blk);
        }
        &self.dense
    }
}

// ------------------------------------------------------------ bit I/O

/// LSB-first bit packer.
#[derive(Debug, Default)]
pub(crate) struct BitWriter {
    bytes: Vec<u8>,
    /// Bits already used in the trailing byte (0 = byte-aligned).
    used: u32,
}

impl BitWriter {
    pub fn push(&mut self, value: u64, bits: u32) {
        debug_assert!(bits <= 64);
        debug_assert!(bits == 64 || value < (1u64 << bits));
        let mut v = value;
        let mut left = bits;
        while left > 0 {
            if self.used == 0 {
                self.bytes.push(0);
            }
            let slot = 8 - self.used;
            let take = slot.min(left);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            *self.bytes.last_mut().unwrap() |= ((v & mask) as u8) << self.used;
            self.used = (self.used + take) % 8;
            v >>= take;
            left -= take;
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// LSB-first bit reader (mirror of [`BitWriter`]).
pub(crate) struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    pub fn read(&mut self, bits: u32) -> u64 {
        let mut out = 0u64;
        let mut got = 0u32;
        while got < bits {
            let byte = self.bytes[self.pos / 8];
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(bits - got);
            let mask = ((1u16 << take) - 1) as u8;
            out |= (((byte >> off) & mask) as u64) << got;
            self.pos += take as usize;
            got += take;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_io_round_trips_mixed_widths() {
        let widths = [1u32, 3, 7, 8, 9, 13, 16, 24, 32];
        let mut w = BitWriter::default();
        for (i, &bits) in widths.iter().enumerate() {
            let v = (0x9e37_79b9u64.wrapping_mul(i as u64 + 1)) & ((1u64 << bits) - 1);
            w.push(v, bits);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (i, &bits) in widths.iter().enumerate() {
            let want = (0x9e37_79b9u64.wrapping_mul(i as u64 + 1)) & ((1u64 << bits) - 1);
            assert_eq!(r.read(bits), want, "field {i} ({bits} bits)");
        }
    }

    #[test]
    fn params_reject_bad_geometry() {
        assert!(CodecParams::new(0, 4, 8, 8).is_err());
        assert!(CodecParams::new(1, 0, 8, 8).is_err());
        assert!(CodecParams::new(1, 4, 0, 8).is_err());
        assert!(CodecParams::new(1, 4, 8, 1).is_err());
        assert!(CodecParams::new(1, 4, 8, 17).is_err());
        // 1024-block at 8 sensor bits breaks the exact-integer bound.
        assert!(CodecParams::new(1, 1024, 8, 8).is_err());
        assert!(CodecParams::new(1, 1024, 4, 8).is_ok());
        assert!(CodecParams::new(1, 256, 8, LOSSLESS).is_ok());
    }

    #[test]
    fn params_arithmetic() {
        let p = CodecParams::new(4, 144, 8, 8).unwrap();
        assert_eq!(p.block(), 256);
        assert_eq!(p.dense_len(), 576);
        assert_eq!(p.coeff_space(), 1024);
        assert_eq!(p.index_bits(), 10);
        assert_eq!(p.value_bits(), 8);
        assert_eq!(p.bands(), 8);
        assert_eq!(p.band_of(0), 0);
        assert_eq!(p.band_of(255), 7);
        let q = CodecParams::new(1, 3, 8, LOSSLESS).unwrap();
        assert_eq!(q.block(), 4);
        assert_eq!(q.bands(), 4);
        assert_eq!(q.value_bits(), 32);
        assert_eq!(q.index_bits(), 2);
    }

    #[test]
    fn snap_is_idempotent_on_grid() {
        let p = CodecParams::new(1, 8, 4, 8).unwrap();
        for k in 0..=16u32 {
            let v = k as f32 / 16.0;
            assert_eq!(p.snap(v), v, "grid value must be a fixed point");
            assert_eq!(p.snap(p.snap(0.123_456)), p.snap(0.123_456));
        }
        assert_eq!(p.snap(-3.0), 0.0);
        assert_eq!(p.snap(7.0), 1.0);
    }
}
