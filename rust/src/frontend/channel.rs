//! Deterministic fault-injecting channel model for compressed-frame
//! transport (the lossy hop between sensor nodes and the edge
//! coordinator; cf. the over-the-air multi-sensor serving setting of
//! arxiv 2501.10245).
//!
//! [`Channel::transmit`] takes one encoded frame's wire bytes and
//! returns what the far end receives: possibly bit-flipped at a
//! configurable BER, truncated, duplicated, reordered with the next
//! frame, or dropped outright. Faults are drawn from
//! `Rng::for_stream(seed, frame_id)` — the same per-stream determinism
//! contract as encoder dither and analog noise — so a fleet test
//! corrupts exactly the same frames run after run, no matter how
//! submission threads interleave.
//!
//! The model is intentionally wire-level only: it never interprets the
//! bytes it damages. Whatever comes out the far end must be survived by
//! [`super::codec::CompressedFrame::from_bytes`], which is the point.

use crate::util::Rng;

/// Fault probabilities for one simulated link. All probabilities are
/// per frame except `ber`, which is per bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChannelConfig {
    /// Bit error rate: each payload bit flips independently.
    pub ber: f64,
    /// Probability the frame is lost entirely.
    pub drop_prob: f64,
    /// Probability the frame is cut short at a random byte boundary.
    pub truncate_prob: f64,
    /// Probability the frame is delivered twice.
    pub duplicate_prob: f64,
    /// Probability the frame is held back and delivered after its
    /// successor (pairwise reordering).
    pub reorder_prob: f64,
    /// Seed of the per-frame fault stream.
    pub seed: u64,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        ChannelConfig {
            ber: 0.0,
            drop_prob: 0.0,
            truncate_prob: 0.0,
            duplicate_prob: 0.0,
            reorder_prob: 0.0,
            seed: 0,
        }
    }
}

impl ChannelConfig {
    /// Reject NaN or out-of-range probabilities before they reach the
    /// RNG (whose `bernoulli` treats NaN as never-true silently).
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [
            ("ber", self.ber),
            ("drop_prob", self.drop_prob),
            ("truncate_prob", self.truncate_prob),
            ("duplicate_prob", self.duplicate_prob),
            ("reorder_prob", self.reorder_prob),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!("channel {name} = {p} outside [0, 1]"));
            }
        }
        Ok(())
    }
}

/// Running per-link fault tally (what the channel *did*, for test
/// assertions and demo output).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChannelStats {
    /// Frames offered to the channel.
    pub offered: u64,
    /// Deliveries out the far end (duplicates count twice).
    pub delivered: u64,
    /// Frames the channel swallowed whole.
    pub dropped: u64,
    /// Frames delivered short (tail cut).
    pub truncated: u64,
    /// Extra copies delivered.
    pub duplicated: u64,
    /// Frames delivered out of submission order.
    pub reordered: u64,
    /// Frames with at least one flipped bit.
    pub corrupted: u64,
    /// Total bits flipped across all corrupted frames.
    pub bits_flipped: u64,
}

impl std::fmt::Display for ChannelStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "channel: offered={} delivered={} dropped={} truncated={} \
             duplicated={} reordered={} corrupted={} (bits={})",
            self.offered,
            self.delivered,
            self.dropped,
            self.truncated,
            self.duplicated,
            self.reordered,
            self.corrupted,
            self.bits_flipped
        )
    }
}

/// One simulated lossy link. Stateful only for pairwise reordering
/// (at most one frame is ever held back); everything else is a pure
/// function of `(config, frame_id, bytes)`.
#[derive(Debug, Clone)]
pub struct Channel {
    cfg: ChannelConfig,
    stats: ChannelStats,
    held: Option<(u64, Vec<u8>)>,
}

impl Channel {
    /// Build a channel, validating the fault probabilities.
    pub fn new(cfg: ChannelConfig) -> Result<Self, String> {
        cfg.validate()?;
        Ok(Channel { cfg, stats: ChannelStats::default(), held: None })
    }

    /// The validated configuration.
    pub fn config(&self) -> ChannelConfig {
        self.cfg
    }

    /// Fault counters accumulated so far.
    pub fn stats(&self) -> ChannelStats {
        self.stats
    }

    /// Push one frame through the link; returns the `(frame_id, bytes)`
    /// deliveries that come out the far end (possibly none, possibly
    /// several once duplication/reordering get involved).
    ///
    /// Fault decisions are always drawn in the same fixed order —
    /// drop, bit flips, truncation, duplication, reordering — so the
    /// outcome for a frame id is independent of channel history.
    pub fn transmit(&mut self, frame_id: u64, bytes: &[u8]) -> Vec<(u64, Vec<u8>)> {
        self.stats.offered += 1;
        let mut rng = Rng::for_stream(self.cfg.seed, frame_id);

        if rng.bernoulli(self.cfg.drop_prob) {
            self.stats.dropped += 1;
            // A drop releases nothing: the held frame keeps waiting for
            // the next successor.
            return Vec::new();
        }

        let mut data = bytes.to_vec();
        if self.cfg.ber > 0.0 {
            let mut flips = 0u64;
            for byte in data.iter_mut() {
                for bit in 0..8 {
                    if rng.bernoulli(self.cfg.ber) {
                        *byte ^= 1 << bit;
                        flips += 1;
                    }
                }
            }
            if flips > 0 {
                self.stats.corrupted += 1;
                self.stats.bits_flipped += flips;
            }
        }
        if rng.bernoulli(self.cfg.truncate_prob) && !data.is_empty() {
            data.truncate(rng.index(data.len()));
            self.stats.truncated += 1;
        }
        let duplicate = rng.bernoulli(self.cfg.duplicate_prob);
        let reorder = rng.bernoulli(self.cfg.reorder_prob);

        let mut out = Vec::new();
        if reorder && self.held.is_none() {
            // Hold this frame back; it rides out behind its successor.
            // (A duplication draw on a held frame is ignored — the
            // decisions are still drawn in fixed order above so other
            // frames' fault streams are unaffected.)
            self.stats.reordered += 1;
            self.held = Some((frame_id, data));
            return out;
        }
        out.push((frame_id, data.clone()));
        if duplicate {
            self.stats.duplicated += 1;
            out.push((frame_id, data));
        }
        if let Some(held) = self.held.take() {
            out.push(held);
        }
        self.stats.delivered += out.len() as u64;
        out
    }

    /// Release any held-back frame (end of stream).
    pub fn flush(&mut self) -> Vec<(u64, Vec<u8>)> {
        let out: Vec<_> = self.held.take().into_iter().collect();
        self.stats.delivered += out.len() as u64;
        out
    }
}

/// Dropping a channel with a frame still in the reorder holdback slot
/// means a call site forgot `flush()` at end of stream — that frame was
/// silently lost, which reads as a phantom drop in loss accounting.
/// Debug builds refuse; release builds stay permissive (a lossy link
/// losing one more frame is degraded telemetry, not corruption).
impl Drop for Channel {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            debug_assert!(
                self.held.is_none(),
                "channel dropped holding reordered frame {:?}: call flush() at end of stream",
                self.held.as_ref().map(|(id, _)| *id)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ChannelConfig {
        ChannelConfig { seed: 0xc4a7, ..ChannelConfig::default() }
    }

    #[test]
    fn clean_channel_is_identity() {
        let mut ch = Channel::new(cfg()).unwrap();
        let frame = vec![1u8, 2, 3, 4];
        assert_eq!(ch.transmit(7, &frame), vec![(7, frame.clone())]);
        assert_eq!(ch.flush(), Vec::new());
        let s = ch.stats();
        assert_eq!((s.offered, s.delivered, s.corrupted), (1, 1, 0));
    }

    #[test]
    fn transmit_is_deterministic_per_frame_id() {
        let noisy = ChannelConfig {
            ber: 0.01,
            drop_prob: 0.1,
            truncate_prob: 0.1,
            duplicate_prob: 0.1,
            reorder_prob: 0.1,
            ..cfg()
        };
        let payload: Vec<u8> = (0..64).collect();
        let mut a = Channel::new(noisy).unwrap();
        let mut b = Channel::new(noisy).unwrap();
        for id in 0..200 {
            assert_eq!(a.transmit(id, &payload), b.transmit(id, &payload), "frame {id}");
        }
        assert_eq!(a.flush(), b.flush());
        assert_eq!(a.stats(), b.stats());
    }

    #[test]
    fn drop_prob_one_drops_everything() {
        let mut ch = Channel::new(ChannelConfig { drop_prob: 1.0, ..cfg() }).unwrap();
        for id in 0..32 {
            assert!(ch.transmit(id, &[0xAA; 16]).is_empty());
        }
        let s = ch.stats();
        assert_eq!((s.offered, s.dropped, s.delivered), (32, 32, 0));
    }

    #[test]
    fn ber_one_flips_every_bit() {
        let mut ch = Channel::new(ChannelConfig { ber: 1.0, ..cfg() }).unwrap();
        let out = ch.transmit(0, &[0x0F, 0xF0]);
        assert_eq!(out, vec![(0, vec![0xF0, 0x0F])]);
        let s = ch.stats();
        assert_eq!((s.corrupted, s.bits_flipped), (1, 16));
    }

    #[test]
    fn reordering_swaps_with_successor_and_flush_releases() {
        let mut ch = Channel::new(ChannelConfig { reorder_prob: 1.0, ..cfg() }).unwrap();
        // First frame is held; the second is also *drawn* reorder=true
        // but the slot is taken, so it carries the held frame out.
        assert!(ch.transmit(1, &[1]).is_empty());
        assert_eq!(ch.transmit(2, &[2]), vec![(2, vec![2]), (1, vec![1])]);
        // Third is held again; flush releases it.
        assert!(ch.transmit(3, &[3]).is_empty());
        assert_eq!(ch.flush(), vec![(3, vec![3])]);
        assert_eq!(ch.stats().delivered, 3);
    }

    #[test]
    fn duplicate_delivers_twice() {
        let mut ch = Channel::new(ChannelConfig { duplicate_prob: 1.0, ..cfg() }).unwrap();
        let out = ch.transmit(5, &[9, 9]);
        assert_eq!(out, vec![(5, vec![9, 9]), (5, vec![9, 9])]);
        assert_eq!(ch.stats().duplicated, 1);
        assert_eq!(ch.stats().delivered, 2);
    }

    #[test]
    fn truncation_never_panics_on_tiny_frames() {
        let mut ch = Channel::new(ChannelConfig { truncate_prob: 1.0, ..cfg() }).unwrap();
        for id in 0..16 {
            for out in ch.transmit(id, &[7]) {
                assert!(out.1.len() <= 1);
            }
            assert!(ch.transmit(1000 + id, &[]).len() <= 1);
        }
    }

    #[test]
    fn config_validation_rejects_bad_probs() {
        assert!(ChannelConfig { ber: -0.1, ..cfg() }.validate().is_err());
        assert!(ChannelConfig { drop_prob: 1.5, ..cfg() }.validate().is_err());
        assert!(ChannelConfig { reorder_prob: f64::NAN, ..cfg() }.validate().is_err());
        assert!(cfg().validate().is_ok());
        assert!(Channel::new(ChannelConfig { ber: 2.0, ..cfg() }).is_err());
    }

    #[test]
    fn stats_display_is_stable() {
        let mut ch = Channel::new(ChannelConfig { duplicate_prob: 1.0, ..cfg() }).unwrap();
        let _ = ch.transmit(0, &[1, 2, 3]);
        let line = ch.stats().to_string();
        assert!(line.contains("offered=1"), "got: {line}");
        assert!(line.contains("duplicated=1"), "got: {line}");
    }
}
