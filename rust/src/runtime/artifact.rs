//! Artifact loading: manifest, weights, golden vectors.
//!
//! The manifest is the JSON written by `python/compile/aot.py`. We parse
//! just what we need with a small scanner (the offline build has no JSON
//! crate); the format is under our control on both sides.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `model.manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Compiled batch dimension of the HLO executables.
    pub batch: usize,
    /// Flattened input dimension per sample.
    pub input: usize,
    /// Number of output classes.
    pub classes: usize,
    /// Hidden width of the reference MLP.
    pub hidden: usize,
    /// Input quantization bit width the model was trained at.
    pub input_bits: u8,
    /// Total `f32` count of the flat weight blob.
    pub total_f32: usize,
    /// (name, shape, offset, len) per parameter, manifest order.
    pub params: Vec<(String, Vec<usize>, usize, usize)>,
}

/// Extract `"key": <int>` from a JSON-ish string (first occurrence
/// after `from`). Returns (value, end position).
fn scan_int(text: &str, key: &str, from: usize) -> Option<(i64, usize)> {
    let pat = format!("\"{key}\":");
    let at = text[from..].find(&pat)? + from + pat.len();
    let rest = text[at..].trim_start();
    let off = text.len() - rest.len();
    let end = rest.find(|c: char| !c.is_ascii_digit() && c != '-')?;
    rest[..end].parse().ok().map(|v| (v, off + end))
}

fn scan_str(text: &str, key: &str, from: usize) -> Option<(String, usize)> {
    let pat = format!("\"{key}\":");
    let at = text[from..].find(&pat)? + from + pat.len();
    let open = text[at..].find('"')? + at + 1;
    let close = text[open..].find('"')? + open;
    Some((text[open..close].to_string(), close))
}

fn scan_int_list(text: &str, key: &str, from: usize) -> Option<(Vec<usize>, usize)> {
    let pat = format!("\"{key}\":");
    let at = text[from..].find(&pat)? + from + pat.len();
    let open = text[at..].find('[')? + at + 1;
    let close = text[open..].find(']')? + open;
    let vals = text[open..close]
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse().unwrap_or(0))
        .collect();
    Some((vals, close))
}

impl Manifest {
    /// Parse the `key: value` manifest text (loud on missing keys).
    pub fn parse(text: &str) -> Result<Manifest> {
        let get = |k: &str| -> Result<i64> {
            scan_int(text, k, 0).map(|(v, _)| v).with_context(|| format!("manifest key {k}"))
        };
        let mut params = Vec::new();
        let mut pos = 0usize;
        while let Some((name, p1)) = scan_str(text, "name", pos) {
            let (shape, p2) = scan_int_list(text, "shape", p1).context("shape")?;
            let (offset, p3) = scan_int(text, "offset", p2).context("offset")?;
            let (len, p4) = scan_int(text, "len", p3).context("len")?;
            params.push((name, shape, offset as usize, len as usize));
            pos = p4;
        }
        if params.is_empty() {
            bail!("manifest has no params");
        }
        Ok(Manifest {
            batch: get("batch")? as usize,
            input: get("input")? as usize,
            classes: get("classes")? as usize,
            hidden: get("hidden")? as usize,
            input_bits: get("input_bits")? as u8,
            total_f32: get("total_f32")? as usize,
            params,
        })
    }

    /// Look up a parameter's (name, shape, offset, len) entry.
    pub fn param(&self, name: &str) -> Option<&(String, Vec<usize>, usize, usize)> {
        self.params.iter().find(|(n, _, _, _)| n == name)
    }
}

/// An artifacts directory with typed accessors.
#[derive(Debug, Clone)]
pub struct Artifacts {
    /// Directory holding the HLO text + weight blobs.
    pub dir: PathBuf,
}

impl Artifacts {
    /// Open an artifacts directory, checking the manifest exists.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.join("model.manifest.txt").exists() {
            bail!("{} has no model.manifest.txt — run `make artifacts`", dir.display());
        }
        Ok(Artifacts { dir })
    }

    /// Default location relative to the repo root, overridable with
    /// `ADCIM_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var("ADCIM_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
    }

    /// Read and parse `model.manifest.txt`.
    pub fn manifest(&self) -> Result<Manifest> {
        let text = std::fs::read_to_string(self.dir.join("model.manifest.txt"))?;
        Manifest::parse(&text)
    }

    /// Path of the `<name>.hlo.txt` HLO text file.
    pub fn hlo_path(&self, name: &str) -> String {
        self.dir.join(format!("{name}.hlo.txt")).to_string_lossy().into_owned()
    }

    /// Read a little-endian f32 binary file.
    pub fn read_f32(&self, name: &str) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join(name))?;
        if bytes.len() % 4 != 0 {
            bail!("{name}: size {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// The flat little-endian `f32` weight blob.
    pub fn weights(&self) -> Result<Vec<f32>> {
        self.read_f32("model.weights.bin")
    }

    /// The held-out conformance input batch.
    pub fn test_batch(&self) -> Result<Vec<f32>> {
        self.read_f32("test_batch.bin")
    }

    /// Reference logits the JAX model produced for [`Artifacts::test_batch`].
    pub fn expected_logits(&self) -> Result<Vec<f32>> {
        self.read_f32("expected_logits.bin")
    }

    /// Labels for the conformance batch, one per line.
    pub fn test_labels(&self) -> Result<Vec<usize>> {
        let text = std::fs::read_to_string(self.dir.join("test_labels.txt"))?;
        Ok(text.split_whitespace().filter_map(|t| t.parse().ok()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "params": [
  {"name": "b1", "shape": [32], "offset": 0, "len": 32},
  {"name": "w1", "shape": [144, 32], "offset": 32, "len": 4608}
 ],
 "total_f32": 4640,
 "batch": 16,
 "input": 144,
 "classes": 10,
 "hidden": 32,
 "input_bits": 4
}"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 16);
        assert_eq!(m.input, 144);
        assert_eq!(m.classes, 10);
        assert_eq!(m.hidden, 32);
        assert_eq!(m.input_bits, 4);
        assert_eq!(m.total_f32, 4640);
        assert_eq!(m.params.len(), 2);
        let (name, shape, off, len) = &m.params[1];
        assert_eq!(name, "w1");
        assert_eq!(shape, &vec![144, 32]);
        assert_eq!((*off, *len), (32, 4608));
    }

    #[test]
    fn param_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.param("b1").is_some());
        assert!(m.param("nope").is_none());
    }

    #[test]
    fn rejects_empty() {
        assert!(Manifest::parse("{}").is_err());
    }
}
