//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The digital-reference inference path: `make artifacts` lowers the L2
//! JAX model (with the L1 Pallas BWHT kernel inlined) to HLO text;
//! this module compiles it on the PJRT CPU client and runs it from the
//! rust hot path. Python is never involved at serve time.
//!
//! See /opt/xla-example/load_hlo for the interchange pattern: HLO *text*
//! (ids reassigned by the parser), lowered with `return_tuple=True` and
//! unwrapped with `to_tuple1` here.

pub mod artifact;
pub mod client;

pub use artifact::{Artifacts, Manifest};
pub use client::{LoadedModel, Runtime};
