//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The digital-reference inference path: `make artifacts` lowers the L2
//! JAX model (with the L1 Pallas BWHT kernel inlined) to HLO text;
//! this module compiles it on the PJRT CPU client and runs it from the
//! rust hot path. Python is never involved at serve time.
//!
//! The PJRT-backed [`client`] is gated behind the off-by-default `xla`
//! feature so the default build runs fully offline; [`artifact`]
//! (manifest/weight loading, shared with the analog path) is always
//! available.
//!
//! See /opt/xla-example/load_hlo for the interchange pattern: HLO *text*
//! (ids reassigned by the parser), lowered with `return_tuple=True` and
//! unwrapped with `to_tuple1` here.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;

pub use artifact::{Artifacts, Manifest};
#[cfg(feature = "xla")]
pub use client::{LoadedModel, Runtime};
