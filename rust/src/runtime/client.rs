//! PJRT CPU client wrapper.

use anyhow::{Context, Result};

/// Owns the PJRT client. One per process; models share it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** module and compile it.
    pub fn load_hlo_text(&self, path: &str) -> Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path}"))?;
        Ok(LoadedModel { exe, path: path.to_string() })
    }
}

/// A compiled executable (one per model variant).
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    path: String,
}

impl LoadedModel {
    /// Source HLO path the executable was compiled from.
    pub fn path(&self) -> &str {
        &self.path
    }

    /// Execute with a single f32 input tensor of `shape`; the module was
    /// lowered with `return_tuple=True`, so unwrap a 1-tuple and return
    /// the flat f32 output.
    pub fn run_f32(&self, input: &[f32], shape: &[usize]) -> Result<Vec<f32>> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims).context("shaping input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit]).context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple")?;
        out.to_vec::<f32>().context("reading f32 output")
    }
}

#[cfg(test)]
mod tests {
    // PJRT integration tests live in rust/tests/runtime_integration.rs —
    // they need `make artifacts` and a process-global PJRT client, which
    // unit tests (one process, parallel threads) would fight over.
}
