//! CiM array network topologies.
//!
//! The fabricated chip (Fig 11) has four 16×32 arrays, A1–A4. A1↔A2
//! realises SRAM-immersed SAR; A1 coupled to A2–A4 realises the flash /
//! hybrid modes. Larger meshes tile the same patterns.

use crate::adc::ImmersedMode;

/// How arrays couple for collaborative digitization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CouplingMode {
    /// Adjacent left/right pairs alternate compute/digitize (Fig 8).
    NearestNeighbour,
    /// Groups of `1 + refs` arrays: one computes, `refs` digitize the
    /// coarse flash stage together (Fig 9). `refs = 2^flash_bits − 1`.
    FlashGroup { refs: usize },
}

impl CouplingMode {
    /// Coupling that realises an [`ImmersedMode`] at `bits` resolution.
    pub fn for_adc_mode(mode: ImmersedMode, bits: u8) -> Self {
        match mode {
            ImmersedMode::Sar => CouplingMode::NearestNeighbour,
            ImmersedMode::Flash | ImmersedMode::Hybrid { .. } => {
                CouplingMode::FlashGroup { refs: mode.neighbours(bits) }
            }
        }
    }

    /// Arrays per coupling group.
    pub fn group_size(&self) -> usize {
        match self {
            CouplingMode::NearestNeighbour => 2,
            CouplingMode::FlashGroup { refs } => 1 + refs,
        }
    }
}

/// A linear arrangement of CiM arrays with a coupling mode.
#[derive(Debug, Clone)]
pub struct Topology {
    n_arrays: usize,
    mode: CouplingMode,
}

impl Topology {
    /// Topology over `n_arrays` coupled arrays (panics if too few).
    pub fn new(n_arrays: usize, mode: CouplingMode) -> Self {
        assert!(n_arrays >= mode.group_size(), "not enough arrays for one coupling group");
        Topology { n_arrays, mode }
    }

    /// The fabricated test chip: 4 arrays, nearest-neighbour coupling.
    pub fn test_chip() -> Self {
        Topology::new(4, CouplingMode::NearestNeighbour)
    }

    /// Arrays in the network.
    pub fn n_arrays(&self) -> usize {
        self.n_arrays
    }

    /// The coupling mode.
    pub fn mode(&self) -> CouplingMode {
        self.mode
    }

    /// Complete coupling groups (leftover arrays stay idle).
    pub fn groups(&self) -> Vec<Vec<usize>> {
        let g = self.mode.group_size();
        (0..self.n_arrays / g).map(|i| (i * g..(i + 1) * g).collect()).collect()
    }

    /// Arrays not in any complete group.
    pub fn idle_arrays(&self) -> usize {
        self.n_arrays % self.mode.group_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coupling_from_adc_mode() {
        assert_eq!(
            CouplingMode::for_adc_mode(ImmersedMode::Sar, 5),
            CouplingMode::NearestNeighbour
        );
        assert_eq!(
            CouplingMode::for_adc_mode(ImmersedMode::Hybrid { flash_bits: 2 }, 5),
            CouplingMode::FlashGroup { refs: 3 }
        );
        assert_eq!(
            CouplingMode::for_adc_mode(ImmersedMode::Flash, 5),
            CouplingMode::FlashGroup { refs: 31 }
        );
    }

    #[test]
    fn test_chip_groups() {
        let t = Topology::test_chip();
        assert_eq!(t.groups(), vec![vec![0, 1], vec![2, 3]]);
        assert_eq!(t.idle_arrays(), 0);
    }

    #[test]
    fn hybrid_grouping_on_test_chip() {
        // A1 + A2..A4 as references: exactly one group of 4.
        let t = Topology::new(4, CouplingMode::FlashGroup { refs: 3 });
        assert_eq!(t.groups(), vec![vec![0, 1, 2, 3]]);
    }

    #[test]
    fn leftovers_are_idle() {
        let t = Topology::new(7, CouplingMode::NearestNeighbour);
        assert_eq!(t.groups().len(), 3);
        assert_eq!(t.idle_arrays(), 1);
    }

    #[test]
    #[should_panic(expected = "not enough arrays")]
    fn rejects_undersized_network() {
        Topology::new(3, CouplingMode::FlashGroup { refs: 3 });
    }
}
