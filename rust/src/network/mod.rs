//! Collaborative CiM array networking (paper §IV-A/B, Figs 1(b), 9, 11).
//!
//! The paper's second contribution is *organisational*: compute-in-SRAM
//! arrays take turns being the computer and being the converter. This
//! module owns the static side of that organisation:
//!
//! - [`topology`] — which arrays couple to which (nearest-neighbour SAR
//!   pairs, 1-to-N flash groups, the fabricated 4-array chip of Fig 11).
//! - [`schedule`] — phase-by-phase role assignment with the safety
//!   invariants (an array never computes and digitizes in the same
//!   phase; every computed MAV is digitized exactly once) and the
//!   throughput/area accounting that justifies the paper's system-level
//!   claim: interleaving halves per-array throughput but the reclaimed
//!   ADC area buys more than 2× the arrays.
//!
//! These are the *static* descriptions; the serving path consumes them
//! in [`crate::cim::pool::CimArrayPool`], which walks an
//! `InterleaveSchedule` phase by phase, dispatches MAV planes to the
//! compute-role arrays and re-enforces both invariants at run time on
//! the live data path.

pub mod schedule;
pub mod topology;

pub use schedule::{InterleaveSchedule, Role};
pub use topology::{CouplingMode, Topology};
