//! Phase-interleaved compute/digitize scheduling.
//!
//! "When the left array computes within-memory scalar product, the right
//! array digitizes … Both arrays then switch their operating modes."
//! (paper §IV-A). This module produces and validates those role
//! schedules and derives the system-level throughput argument: with the
//! dedicated-ADC area reclaimed, more arrays fit in the same floorplan
//! and total throughput rises even though each array now computes only
//! every other phase.

use crate::energy::{adc_area_um2, sram_array_area_um2, AdcStyle};

use super::topology::{CouplingMode, Topology};

/// Role of one array in one phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Computing an in-memory scalar product (produces a MAV).
    Compute,
    /// Digitizing a neighbour's MAV.
    Digitize,
    /// Not part of a complete coupling group this phase.
    Idle,
}

/// A phase-major role table.
#[derive(Debug, Clone)]
pub struct InterleaveSchedule {
    /// `roles[phase][array]`.
    roles: Vec<Vec<Role>>,
}

impl InterleaveSchedule {
    /// Build the alternating schedule for `phases` phases.
    ///
    /// Nearest-neighbour: within each pair, one array computes while the
    /// other digitizes; roles swap every phase. Flash groups: the
    /// compute role rotates through the group (the paper's Fig 9 bottom
    /// timeline) while the rest serve as references.
    pub fn build(topology: &Topology, phases: usize) -> Self {
        let n = topology.n_arrays();
        let mut roles = vec![vec![Role::Idle; n]; phases];
        for group in topology.groups() {
            for (ph, row) in roles.iter_mut().enumerate() {
                match topology.mode() {
                    CouplingMode::NearestNeighbour => {
                        let (a, b) = (group[0], group[1]);
                        if ph % 2 == 0 {
                            row[a] = Role::Compute;
                            row[b] = Role::Digitize;
                        } else {
                            row[a] = Role::Digitize;
                            row[b] = Role::Compute;
                        }
                    }
                    CouplingMode::FlashGroup { .. } => {
                        let computer = group[ph % group.len()];
                        for &arr in &group {
                            row[arr] =
                                if arr == computer { Role::Compute } else { Role::Digitize };
                        }
                    }
                }
            }
        }
        InterleaveSchedule { roles }
    }

    /// Build a degraded schedule over only the *healthy* members of
    /// each coupling group: arrays with `down[array] == true` are
    /// pinned to [`Role::Idle`], the compute role rotates through the
    /// survivors (the remaining healthy members serve as references),
    /// and a group with no healthy member goes fully idle — the pool's
    /// fault layer remaps its planes onto another group. With an
    /// all-false mask this produces exactly
    /// [`InterleaveSchedule::build`].
    ///
    /// Degraded schedules deliberately relax the reference-count
    /// invariant of [`InterleaveSchedule::validate`] (a nearest
    /// neighbour pair that lost one member computes every phase with
    /// no digitize partner), so they are consumed by the fault-aware
    /// dispatch path only and are never `validate`d.
    pub fn build_degraded(topology: &Topology, phases: usize, down: &[bool]) -> Self {
        assert_eq!(down.len(), topology.n_arrays(), "down-mask length != arrays");
        let n = topology.n_arrays();
        let mut roles = vec![vec![Role::Idle; n]; phases];
        for group in topology.groups() {
            let healthy: Vec<usize> = group.iter().copied().filter(|&a| !down[a]).collect();
            if healthy.is_empty() {
                continue;
            }
            for (ph, row) in roles.iter_mut().enumerate() {
                let computer = healthy[ph % healthy.len()];
                for &arr in &healthy {
                    row[arr] = if arr == computer { Role::Compute } else { Role::Digitize };
                }
            }
        }
        InterleaveSchedule { roles }
    }

    /// Phases in one full rotation.
    pub fn phases(&self) -> usize {
        self.roles.len()
    }

    /// What `array` does during `phase`.
    pub fn role(&self, phase: usize, array: usize) -> Role {
        self.roles[phase][array]
    }

    /// Safety invariants (property-tested):
    /// 1. no array is double-booked within a phase (structural here, but
    ///    validated for defence against future schedule kinds);
    /// 2. every Compute in phase `p` has a Digitize partner in `p`;
    /// 3. across consecutive phases of a NN pair, roles alternate so
    ///    every computed MAV gets digitized in-place before the array
    ///    recomputes.
    pub fn validate(&self, topology: &Topology) -> Result<(), String> {
        for (ph, row) in self.roles.iter().enumerate() {
            for group in topology.groups() {
                let computes = group.iter().filter(|&&a| row[a] == Role::Compute).count();
                let digitizes = group.iter().filter(|&&a| row[a] == Role::Digitize).count();
                match topology.mode() {
                    CouplingMode::NearestNeighbour => {
                        if computes != 1 || digitizes != 1 {
                            return Err(format!(
                                "phase {ph} group {group:?}: {computes} compute / {digitizes} digitize"
                            ));
                        }
                    }
                    CouplingMode::FlashGroup { refs } => {
                        if computes != 1 || digitizes != refs {
                            return Err(format!(
                                "phase {ph} group {group:?}: {computes} compute / {digitizes} refs"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// MAVs produced per phase across the network.
    pub fn throughput_per_phase(&self) -> f64 {
        if self.roles.is_empty() {
            return 0.0;
        }
        let total: usize = self
            .roles
            .iter()
            .map(|row| row.iter().filter(|&&r| r == Role::Compute).count())
            .sum();
        total as f64 / self.roles.len() as f64
    }
}

/// System-level area/throughput comparison (the paper's §IV-A argument):
/// given a silicon budget, how much MAV throughput does a dedicated-ADC
/// design get vs the collaborative design?
#[derive(Debug, Clone, Copy)]
pub struct SystemComparison {
    /// Arrays that fit with one dedicated ADC per array.
    pub dedicated_arrays: usize,
    /// Arrays that fit with memory-immersed conversion.
    pub collaborative_arrays: usize,
    /// MAV/phase with dedicated ADCs (every array computes every phase).
    pub dedicated_throughput: f64,
    /// MAV/phase with interleaved collaboration (half the arrays compute).
    pub collaborative_throughput: f64,
}

/// Fill a silicon budget (µm²) with (array + converter) tiles and
/// compare throughput. Array geometry: `rows × cols` at `tech_nm`.
pub fn system_comparison(
    budget_um2: f64,
    rows: usize,
    cols: usize,
    tech_nm: f64,
    bits: u8,
) -> SystemComparison {
    let array = sram_array_area_um2(rows, cols, tech_nm);
    let dedicated_tile = array + adc_area_um2(AdcStyle::Sar, bits);
    let collaborative_tile = array + adc_area_um2(AdcStyle::InMemorySar, bits);
    let dedicated_arrays = (budget_um2 / dedicated_tile) as usize;
    let collaborative_arrays = (budget_um2 / collaborative_tile) as usize;
    SystemComparison {
        dedicated_arrays,
        collaborative_arrays,
        dedicated_throughput: dedicated_arrays as f64,
        // Interleaving: half the arrays compute per phase.
        collaborative_throughput: collaborative_arrays as f64 / 2.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn nn_schedule_alternates() {
        let t = Topology::test_chip();
        let s = InterleaveSchedule::build(&t, 4);
        s.validate(&t).unwrap();
        assert_eq!(s.role(0, 0), Role::Compute);
        assert_eq!(s.role(0, 1), Role::Digitize);
        assert_eq!(s.role(1, 0), Role::Digitize);
        assert_eq!(s.role(1, 1), Role::Compute);
    }

    #[test]
    fn flash_group_rotates_computer() {
        let t = Topology::new(4, CouplingMode::FlashGroup { refs: 3 });
        let s = InterleaveSchedule::build(&t, 8);
        s.validate(&t).unwrap();
        let computers: Vec<usize> = (0..4)
            .map(|ph| (0..4).find(|&a| s.role(ph, a) == Role::Compute).unwrap())
            .collect();
        assert_eq!(computers, vec![0, 1, 2, 3]);
    }

    #[test]
    fn prop_schedules_always_valid() {
        prop::check("interleave schedules valid", 128, |rng| {
            let mode = if rng.bool() {
                CouplingMode::NearestNeighbour
            } else {
                CouplingMode::FlashGroup { refs: 1 + rng.index(4) }
            };
            let n = mode.group_size() * (1 + rng.index(5)) + rng.index(mode.group_size());
            let t = Topology::new(n, mode);
            let s = InterleaveSchedule::build(&t, 1 + rng.index(12));
            s.validate(&t)
        });
    }

    #[test]
    fn degraded_schedule_idles_down_arrays_and_matches_build_when_healthy() {
        let t = Topology::new(4, CouplingMode::FlashGroup { refs: 3 });
        let full = InterleaveSchedule::build(&t, 8);
        let same = InterleaveSchedule::build_degraded(&t, 8, &[false; 4]);
        for ph in 0..8 {
            for a in 0..4 {
                assert_eq!(full.role(ph, a), same.role(ph, a), "phase {ph} array {a}");
            }
        }
        let degraded = InterleaveSchedule::build_degraded(&t, 8, &[false, true, false, false]);
        for ph in 0..8 {
            assert_eq!(degraded.role(ph, 1), Role::Idle, "down array must idle");
            let computes =
                (0..4).filter(|&a| degraded.role(ph, a) == Role::Compute).count();
            assert_eq!(computes, 1, "phase {ph}: compute rotates through survivors");
        }
        // Compute rotation covers exactly the healthy members.
        let computers: Vec<usize> = (0..3)
            .map(|ph| (0..4).find(|&a| degraded.role(ph, a) == Role::Compute).unwrap())
            .collect();
        assert_eq!(computers, vec![0, 2, 3]);
    }

    #[test]
    fn degraded_schedule_idles_fully_down_group() {
        let t = Topology::new(4, CouplingMode::NearestNeighbour);
        let s = InterleaveSchedule::build_degraded(&t, 4, &[true, true, false, false]);
        for ph in 0..4 {
            assert_eq!(s.role(ph, 0), Role::Idle);
            assert_eq!(s.role(ph, 1), Role::Idle);
            let live =
                (2..4).filter(|&a| s.role(ph, a) == Role::Compute).count();
            assert_eq!(live, 1, "healthy pair keeps alternating");
        }
        // A solo survivor computes every phase (no digitize partner).
        let solo = InterleaveSchedule::build_degraded(&t, 4, &[false, true, true, true]);
        for ph in 0..4 {
            assert_eq!(solo.role(ph, 0), Role::Compute);
        }
    }

    #[test]
    fn nn_throughput_is_half_the_paired_arrays() {
        let t = Topology::new(8, CouplingMode::NearestNeighbour);
        let s = InterleaveSchedule::build(&t, 6);
        assert!((s.throughput_per_phase() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn collaboration_wins_when_arrays_are_small() {
        // The paper's 16×32 arrays: the SAR ADC dwarfs the array, so the
        // collaborative design fits >2× the arrays and wins throughput.
        let c = system_comparison(1.0e6, 16, 32, 65.0, 5);
        assert!(c.collaborative_arrays > 2 * c.dedicated_arrays);
        assert!(
            c.collaborative_throughput > c.dedicated_throughput,
            "collab {} vs dedicated {}",
            c.collaborative_throughput,
            c.dedicated_throughput
        );
    }

    #[test]
    fn dedicated_wins_for_huge_arrays() {
        // Sanity: when the array dwarfs the ADC, dedicated conversion's
        // 2× duty-cycle advantage dominates — the trade-off is real.
        let c = system_comparison(1.0e8, 1024, 1024, 65.0, 5);
        assert!(c.dedicated_throughput > c.collaborative_throughput);
    }
}
