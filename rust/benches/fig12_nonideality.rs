//! Bench: Fig 12 — staircase/DNL/INL + characterization sweep cost.

use adcim::adc::metrics::linearity;
use adcim::adc::{ImmersedAdc, ImmersedMode};
use adcim::analog::NoiseModel;
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig12::generate());

    let mut set = BenchSet::new("full linearity characterization");
    let noise = NoiseModel::default();
    let mut rng = Rng::new(5);
    let mut adc = ImmersedAdc::sample(5, 1.0, ImmersedMode::Sar, 32, 20.0, &noise, &mut rng);
    let mut r = Rng::new(6);
    set.run("5-bit DNL/INL ramp (32 steps/code)", move || {
        black_box(linearity(&mut adc, 32, &mut r));
    });
}
