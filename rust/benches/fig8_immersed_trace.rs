//! Bench: Fig 8 — immersed SAR conversion trace + cycle-level cost.

use adcim::adc::{Adc, ImmersedAdc, ImmersedMode};
use adcim::analog::NoiseModel;
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig8::generate());

    let mut set = BenchSet::new("immersed conversion cost");
    let noise = NoiseModel::default();
    for (name, mode) in [
        ("SAR (5 cycles)", ImmersedMode::Sar),
        ("hybrid (4 cycles)", ImmersedMode::Hybrid { flash_bits: 2 }),
        ("flash (1 cycle)", ImmersedMode::Flash),
    ] {
        let mut rng = Rng::new(7);
        let mut adc = ImmersedAdc::sample(5, 1.0, mode, 32, 20.0, &noise, &mut rng);
        let mut r = Rng::new(8);
        let mut v = 0.0f64;
        set.run(name, move || {
            v = (v + 0.618).fract();
            black_box(adc.convert(v, &mut r));
        });
    }
}
