//! Bench: whole-stack hot paths — the §Perf working set. Run before and
//! after optimizations; EXPERIMENTS.md §Perf records the deltas and
//! `BENCH_hotpath.json` (written at the end of this run, path
//! overridable via `BENCH_JSON`) is the machine-readable trajectory.
//!
//! The `(seed baseline)` cases re-implement the pre-optimization
//! algorithms *inside this binary* — per-row five-Gaussian noise with a
//! fresh `Vec<bool>` per crossbar op, scalar-accumulator dense matvec —
//! so one run measures before and after on identical hardware.

use adcim::adc::ImmersedMode;
use adcim::analog::timing::Phase;
use adcim::analog::{Comparator, NoiseModel, OperatingPoint, PhaseTimer, SupplyModel};
use adcim::cim::{
    BitplaneEngine, BitVec, CimArrayPool, Crossbar, CrossbarConfig, PoolSpec, SignMatrix,
};
use adcim::coordinator::{AnalogEngine, FramePayload, InferenceEngine};
use adcim::frontend::{CodecParams, FrameEncoder, Selection};
use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::layer::dot_f32;
use adcim::nn::model::bwht_mlp;
use adcim::nn::Tensor;
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::{Executor, Rng};
use std::sync::Arc;
use adcim::wht::{fwht_inplace, Bwht};

/// The seed's crossbar inner loop, reproduced verbatim in shape: per row
/// two dead-cell thinning draws, two kT/C draws, two spread draws and a
/// noisy compare, plus a fresh `Vec<bool>` allocation per operation.
struct SeedCrossbar {
    matrix: SignMatrix,
    comparators: Vec<Comparator>,
    vdd: f64,
    settle: f64,
    p_dead: f64,
    spread: f64,
    ktc_sigma: f64,
}

impl SeedCrossbar {
    fn walsh(m: usize, rng: &mut Rng) -> Self {
        let supply = SupplyModel::default();
        let noise = NoiseModel::default();
        let op = OperatingPoint::crossbar_nominal();
        let timer = PhaseTimer::new(supply, op);
        let settle = timer.settle(Phase::LocalCompute) * timer.settle(Phase::RowMergeSum);
        let mut p_dead = supply.dead_cell_prob(op.vdd, noise.vth_mismatch_sigma_v);
        if p_dead < 1e-9 {
            p_dead = 0.0;
        }
        let mut spread = supply.settle_vth_sensitivity(op.vdd, timer.step_time_ps())
            * noise.vth_mismatch_sigma_v;
        if spread < 1e-4 {
            spread = 0.0;
        }
        let ktc_sigma =
            adcim::analog::noise::ktc_noise_v(m as f64 * 1.2, noise.temp_k);
        SeedCrossbar {
            matrix: SignMatrix::walsh(m),
            comparators: (0..m).map(|_| Comparator::sample(&noise, rng)).collect(),
            vdd: op.vdd,
            settle,
            p_dead,
            spread,
            ktc_sigma,
        }
    }

    fn row_sum_voltages(&self, r: usize, x: &BitVec, rng: &mut Rng) -> (f64, f64) {
        let cols = self.matrix.cols() as f64;
        let mut plus = self.matrix.row_plus_count(r, x) as f64;
        let ones = x.count_ones() as f64;
        let mut minus = ones - plus;
        if self.p_dead > 0.0 {
            let thin = |count: f64, rng: &mut Rng| -> f64 {
                let mean = count * (1.0 - self.p_dead);
                let sigma = (count * self.p_dead * (1.0 - self.p_dead)).sqrt();
                (mean + rng.normal() * sigma).max(0.0)
            };
            plus = thin(plus, rng);
            minus = thin(minus, rng);
        }
        let mut v_sl = self.vdd * (plus / cols) * self.settle;
        let mut v_slb = self.vdd * (minus / cols) * self.settle;
        if self.ktc_sigma > 0.0 {
            v_sl += rng.normal() * self.ktc_sigma;
            v_slb += rng.normal() * self.ktc_sigma;
        }
        if self.spread > 0.0 {
            let scale = self.vdd * self.spread / cols;
            v_sl += rng.normal() * scale * plus.sqrt();
            v_slb += rng.normal() * scale * minus.sqrt();
        }
        (v_sl.clamp(0.0, self.vdd), v_slb.clamp(0.0, self.vdd))
    }

    fn process_bitplane(&mut self, x: &BitVec, rng: &mut Rng) -> Vec<bool> {
        (0..self.matrix.rows())
            .map(|r| {
                let (sl, slb) = self.row_sum_voltages(r, x, rng);
                self.comparators[r].compare(sl, slb, rng)
            })
            .collect()
    }
}

/// The seed's scalar-accumulator dense matvec (latency-chained FP adds).
fn seed_matvec(w: &[f32], b: &[f32], x: &[f32], out_dim: usize, y: &mut [f32]) {
    let in_dim = x.len();
    for o in 0..out_dim {
        let row = &w[o * in_dim..(o + 1) * in_dim];
        let mut acc = b[o];
        for (wi, xi) in row.iter().zip(x) {
            acc += wi * xi;
        }
        y[o] = acc;
    }
}

fn main() {
    let mut set = BenchSet::new("L3 hot paths");

    // FWHT butterfly (digital reference transform).
    for m in [64usize, 1024, 4096] {
        let mut x: Vec<f32> = (0..m).map(|i| i as f32).collect();
        set.run(&format!("fwht m={m}"), move || {
            fwht_inplace(black_box(&mut x));
        });
    }

    // BWHT layer-scale transform.
    let b = Bwht::for_dim(960, 512);
    let x: Vec<f32> = (0..960).map(|i| (i as f32).sin()).collect();
    set.run("bwht 960ch (MobileNetV2 head dim)", move || {
        black_box(b.forward(&x));
    });

    // Crossbar bitplane op (the analog inner loop), seed baseline vs the
    // folded-noise packed pipeline.
    let mut rng = Rng::new(1);
    for m in [32usize, 128] {
        let mut seed_xb = SeedCrossbar::walsh(m, &mut rng.clone());
        let x = BitVec::from_bits(&(0..m).map(|i| i % 2 == 0).collect::<Vec<_>>());
        let mut r = Rng::new(2);
        let xs = x.clone();
        set.run(&format!("crossbar {m}x{m} bitplane (seed baseline)"), move || {
            black_box(seed_xb.process_bitplane(&xs, &mut r));
        });

        let mut xb = Crossbar::walsh(m, CrossbarConfig::default(), &mut rng);
        let mut r = Rng::new(2);
        let mut out = BitVec::zeros(m);
        let xs = x.clone();
        set.run(&format!("crossbar {m}x{m} bitplane"), move || {
            xb.process_bitplane_into(black_box(&xs), &mut r, &mut out);
            black_box(&out);
        });

        // The zero-noise oracle path: pure popcount, no RNG.
        let mut ideal = Crossbar::walsh(m, CrossbarConfig::ideal(), &mut rng);
        let mut r = Rng::new(2);
        let mut out = BitVec::zeros(m);
        set.run(&format!("crossbar {m}x{m} bitplane (ideal popcount)"), move || {
            ideal.process_bitplane_into(black_box(&x), &mut r, &mut out);
            black_box(&out);
        });
    }

    // Multi-bit engine transform (4 planes) and the batched API.
    let mut eng = BitplaneEngine::new(
        Crossbar::walsh(32, CrossbarConfig::default(), &mut Rng::new(3)),
        4,
    );
    let xq: Vec<u32> = (0..32).map(|i| (i as u32 * 3) % 16).collect();
    let mut r = Rng::new(4);
    set.run("bitplane engine 32ch 4-bit", move || {
        black_box(eng.transform(&xq, &mut r));
    });

    let mut eng = BitplaneEngine::new(
        Crossbar::walsh(32, CrossbarConfig::default(), &mut Rng::new(3)),
        4,
    );
    let batch: Vec<Vec<u32>> = (0..16)
        .map(|s| (0..32).map(|i| ((i * 3 + s) % 16) as u32).collect())
        .collect();
    set.run("bitplane engine transform_batch x16", move || {
        black_box(eng.transform_batch(&batch, 0x5eed));
    });

    // Collaborative digitization pool: the multi-bit serving path (4
    // arrays, one scheduled phase + 32 conversions per plane). One case
    // per converter networking mode; the printed info line reports
    // conversions/s and conversion energy per transform so BENCH JSON
    // carries both time and energy.
    let pool_modes: [(&str, ImmersedMode, u8); 4] = [
        ("sar", ImmersedMode::Sar, 5),
        ("flash", ImmersedMode::Flash, 2),
        ("hybrid f2", ImmersedMode::Hybrid { flash_bits: 2 }, 5),
        ("sar asym", ImmersedMode::Sar, 5),
    ];
    for (label, mode, adc_bits) in pool_modes {
        let spec = PoolSpec {
            n_arrays: 4,
            adc_bits,
            mode,
            asymmetric: label.ends_with("asym"),
            threads: 1,
            fuse_batch: false,
        };
        let mut fab = Rng::new(31);
        let matrix = SignMatrix::walsh(32);
        let mk = |fab: &mut Rng| {
            BitplaneEngine::new(
                Crossbar::new(matrix.clone(), CrossbarConfig::default(), fab),
                4,
            )
            .with_pool(CimArrayPool::new(&matrix, CrossbarConfig::default(), spec, fab))
        };
        // One probe transform for the energy/conversion info line.
        let mut probe = mk(&mut fab.clone());
        let xq: Vec<u32> = (0..32).map(|i| (i as u32 * 3) % 16).collect();
        let out = probe.transform(&xq, &mut Rng::new(5));
        println!(
            "pool 4x32 {label}: {} conversions/transform, {:.2} cmp/conv, {:.1} fJ/transform",
            out.conv.conversions,
            out.conv.comparisons_per_conversion(),
            out.conv.energy_fj
        );
        let mut eng = mk(&mut fab);
        let mut r = Rng::new(6);
        let xb = xq.clone();
        set.run(&format!("pool 4x32 {label} transform 4-bit"), move || {
            black_box(eng.transform(&xb, &mut r));
        });
    }

    // Batched plane fan-out: an 8-array SAR pool has 4 independent
    // coupling groups; process_planes queues 8 planes (two rotations)
    // onto per-group lanes, run inline vs on the pool's persistent
    // worker runtime (spawned once at the first parallel call, reused
    // by every iteration after). Same outputs by the per-plane stream
    // contract — this case pair measures the fan-out win itself.
    for threads in [1usize, 4] {
        let spec = PoolSpec {
            n_arrays: 8,
            adc_bits: 5,
            mode: ImmersedMode::Sar,
            asymmetric: false,
            threads,
            fuse_batch: false,
        };
        let matrix = SignMatrix::walsh(32);
        let mut pool =
            CimArrayPool::new(&matrix, CrossbarConfig::default(), spec, &mut Rng::new(41));
        let planes: Vec<BitVec> = (0..8)
            .map(|s| {
                BitVec::from_bits(&(0..32).map(|i| (i * 7 + s * 13) % 3 == 0).collect::<Vec<_>>())
            })
            .collect();
        let streams: Vec<u64> = (0..8).collect();
        let mut out = vec![0.0f64; 8 * 32];
        set.run(&format!("pool 8x32 sar process_planes x8 t={threads}"), move || {
            pool.begin_transform();
            let refs: Vec<&BitVec> = planes.iter().collect();
            pool.process_planes(&refs, &streams, 0x5eed, None, &mut out);
            black_box(&out);
        });
    }

    // The PR-3 per-call-spawn ceiling, measured honestly: identical
    // work to `t=4` above, but a fresh 4-lane runtime is built (threads
    // spawned) and dropped (joined) inside every call — the cost shape
    // `thread::scope` paid per `process_planes` before the persistent
    // executor.
    {
        let spec = PoolSpec {
            n_arrays: 8,
            adc_bits: 5,
            mode: ImmersedMode::Sar,
            asymmetric: false,
            threads: 4,
            fuse_batch: false,
        };
        let matrix = SignMatrix::walsh(32);
        let mut pool =
            CimArrayPool::new(&matrix, CrossbarConfig::default(), spec, &mut Rng::new(41));
        let planes: Vec<BitVec> = (0..8)
            .map(|s| {
                BitVec::from_bits(&(0..32).map(|i| (i * 7 + s * 13) % 3 == 0).collect::<Vec<_>>())
            })
            .collect();
        let streams: Vec<u64> = (0..8).collect();
        let mut out = vec![0.0f64; 8 * 32];
        set.run("pool 8x32 sar process_planes x8 t=4 (per-call spawn baseline)", move || {
            pool.set_executor(Some(Arc::new(Executor::new(4))));
            pool.begin_transform();
            let refs: Vec<&BitVec> = planes.iter().collect();
            pool.process_planes(&refs, &streams, 0x5eed, None, &mut out);
            black_box(&out);
        });
    }

    // Cross-sample plane fusion: a 16-sample 4-bit batch through an
    // 8-array pooled engine. Unfused, each sample drains the pool alone
    // (16 submissions); fused, all 64 planes reach the coupling-group
    // lanes in one submission, so lanes stay saturated across sample
    // boundaries. Outputs bit-identical either way
    // (tests/executor_fusion.rs).
    for threads in [1usize, 4] {
        let spec = PoolSpec {
            n_arrays: 8,
            adc_bits: 5,
            mode: ImmersedMode::Sar,
            asymmetric: false,
            threads,
            fuse_batch: true,
        };
        let matrix = SignMatrix::walsh(32);
        let mut fab = Rng::new(31);
        let mut eng = BitplaneEngine::new(
            Crossbar::new(matrix.clone(), CrossbarConfig::default(), &mut fab),
            4,
        )
        .with_pool(CimArrayPool::new(&matrix, CrossbarConfig::default(), spec, &mut fab));
        let fused_batch: Vec<Vec<u32>> = (0..16)
            .map(|s| (0..32).map(|i| ((i * 3 + s) % 16) as u32).collect())
            .collect();
        set.run(&format!("pool 8x32 sar fused b=16 t={threads}"), move || {
            black_box(eng.transform_batch(&fused_batch, 0x5eed));
        });
    }

    // Per-row conversion gating: the same pooled transform with a wide
    // exact-ET dead band converts a fraction of the rows — the ET
    // savings the ADC energy column sees. The probe line reports the
    // gated/converted split.
    {
        let spec = PoolSpec {
            n_arrays: 4,
            adc_bits: 5,
            mode: ImmersedMode::Sar,
            asymmetric: false,
            threads: 1,
            fuse_batch: false,
        };
        let matrix = SignMatrix::walsh(32);
        let mk = || {
            let mut fab = Rng::new(31);
            let mut eng = BitplaneEngine::new(
                Crossbar::new(matrix.clone(), CrossbarConfig::default(), &mut fab),
                4,
            )
            .with_pool(CimArrayPool::new(&matrix, CrossbarConfig::default(), spec, &mut fab));
            eng.early_term = Some(adcim::cim::EarlyTermination::exact(8.0));
            eng
        };
        let xq: Vec<u32> = (0..32).map(|i| (i as u32 * 3) % 16).collect();
        let probe = mk().transform(&xq, &mut Rng::new(5));
        println!(
            "pool 4x32 sar gated-ET: {} conversions + {} gated per transform, {:.1} fJ",
            probe.conv.conversions, probe.conv.gated, probe.conv.energy_fj
        );
        let mut eng = mk();
        let mut r = Rng::new(6);
        set.run("pool 4x32 sar gated-ET transform 4-bit", move || {
            black_box(eng.transform(&xq, &mut r));
        });
    }

    // Dense matvec: seed scalar-accumulator baseline vs unrolled dot.
    let mut wr = Rng::new(5);
    let w = wr.normal_vec(144 * 32);
    let bias = wr.normal_vec(32);
    let xv = wr.normal_vec(144);
    let mut y = vec![0.0f32; 32];
    {
        let (w, bias, xv) = (w.clone(), bias.clone(), xv.clone());
        set.run("dense 144x32 matvec (seed baseline)", move || {
            seed_matvec(black_box(&w), &bias, &xv, 32, &mut y);
            black_box(&y);
        });
    }
    set.run("dense 144x32 matvec (unrolled)", move || {
        let mut acc = 0.0f32;
        for o in 0..32 {
            acc += bias[o] + dot_f32(black_box(&w[o * 144..(o + 1) * 144]), &xv);
        }
        black_box(acc);
    });

    // Full model forward (analog BWHT digit MLP, float mode): the
    // serving path (forward_inference) vs the training forward.
    let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(5));
    let img = Tensor::vec1(&vec![0.5f32; 144]);
    {
        let imgc = img.clone();
        set.run("digit MLP forward (train path)", move || {
            black_box(model.forward(&imgc));
        });
    }
    let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(5));
    let imgc = img.clone();
    set.run("digit MLP forward (float)", move || {
        black_box(model.forward_inference(&imgc));
    });

    // Sensor-frontend encode: 8-channel 256-sample frames (the ISSUE-4
    // deluge shape) through snap + sequency FWHT + global top-K + pack.
    for k in [16usize, 64] {
        let params = CodecParams::new(8, 256, 8, 8).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::TopK(k));
        let frame: Vec<f32> = (0..params.dense_len())
            .map(|i| 0.5 + 0.4 * ((i as f32) * 0.13).sin())
            .collect();
        set.run(&format!("frontend encode 256x8ch topk{k}"), move || {
            black_box(enc.encode(black_box(&frame), 0));
        });
    }

    // Compressed-domain serving: 32 lossy top-16 frames through the
    // analog digit MLP's folded first layer (no reconstruction).
    {
        let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(5));
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 7,
                pool: None,
            })
        });
        let mut engine = AnalogEngine::from_model(model, 144);
        let params = CodecParams::new(1, 144, 8, 8).unwrap();
        let mut enc = FrameEncoder::new(params, Selection::TopK(16));
        let payloads: Vec<FramePayload> = (0..32)
            .map(|i| {
                let frame: Vec<f32> =
                    (0..144).map(|j| ((i * j + i) % 9) as f32 / 9.0).collect();
                FramePayload::Compressed(enc.encode(&frame, i as u64))
            })
            .collect();
        set.run("analog MLP compressed-serve b=32 topk16", move || {
            black_box(engine.infer_payloads(&payloads).unwrap());
        });
    }

    // Batched analog inference: thread-sharded engine, same model/seed.
    for threads in [1usize, 4] {
        let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(5));
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 7,
                pool: None,
            })
        });
        let mut engine = AnalogEngine::from_model(model, 144).with_threads(threads);
        let images: Vec<Vec<f32>> =
            (0..32).map(|i| vec![(i % 5) as f32 * 0.2; 144]).collect();
        set.run(&format!("analog MLP infer_batch b=32 t={threads}"), move || {
            black_box(engine.infer_batch(&images).unwrap());
        });
    }

    // The same sharded batch on an explicitly pre-warmed persistent
    // runtime: the first parallel batch builds the executor outside the
    // measurement window, so this row is the steady-state serving cost
    // — per-batch spawn/join fully off the hot path.
    {
        let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(5));
        model.for_each_bwht(|b| {
            b.set_exec(BwhtExec::Analog {
                input_bits: 4,
                config: CrossbarConfig::default(),
                early_term: None,
                seed: 7,
                pool: None,
            })
        });
        let mut engine = AnalogEngine::from_model(model, 144).with_threads(4);
        let images: Vec<Vec<f32>> =
            (0..32).map(|i| vec![(i % 5) as f32 * 0.2; 144]).collect();
        let _ = engine.infer_batch(&images).unwrap(); // warm the runtime
        set.run("analog MLP infer_batch b=32 t=4 (executor)", move || {
            black_box(engine.infer_batch(&images).unwrap());
        });
    }

    // Lockstep batched serving (ISSUE 7): a pooled fuse-batch engine
    // serving whole request batches through ONE multi-sample forward —
    // every sample's bitplanes across all BWHT blocks reach the pool in
    // a single submission. The per-sample baseline runs the identical
    // engine with the lockstep walk disabled (`with_lockstep(false)`):
    // same logits and conversion accounting bit-for-bit
    // (tests/batched_forward.rs), different pool occupancy.
    {
        let mk = |lockstep: bool| {
            let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(5));
            model.for_each_bwht(|b| {
                b.set_exec(BwhtExec::Analog {
                    input_bits: 4,
                    config: CrossbarConfig::default(),
                    early_term: None,
                    seed: 7,
                    pool: Some(PoolSpec {
                        n_arrays: 4,
                        adc_bits: 5,
                        mode: ImmersedMode::Sar,
                        asymmetric: false,
                        threads: 1,
                        fuse_batch: true,
                    }),
                })
            });
            AnalogEngine::from_model(model, 144).with_lockstep(lockstep)
        };
        for b in [4usize, 16, 64] {
            let mut engine = mk(true);
            let images: Vec<Vec<f32>> =
                (0..b).map(|i| vec![(i % 5) as f32 * 0.2; 144]).collect();
            set.run(&format!("analog MLP serve-batch b={b} fused"), move || {
                black_box(engine.infer_batch(&images).unwrap());
            });
        }
        let mut engine = mk(false);
        let images: Vec<Vec<f32>> =
            (0..16).map(|i| vec![(i % 5) as f32 * 0.2; 144]).collect();
        set.run("analog MLP serve-batch b=16 per-sample baseline", move || {
            black_box(engine.infer_batch(&images).unwrap());
        });
    }

    let path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    match set.write_json(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
