//! Bench: whole-stack hot paths — the §Perf working set. Run before and
//! after optimizations; EXPERIMENTS.md §Perf records the deltas.

use adcim::cim::{BitplaneEngine, BitVec, Crossbar, CrossbarConfig};
use adcim::nn::model::bwht_mlp;
use adcim::nn::Tensor;
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::Rng;
use adcim::wht::{fwht_inplace, Bwht};

fn main() {
    let mut set = BenchSet::new("L3 hot paths");

    // FWHT butterfly (digital reference transform).
    for m in [64usize, 1024, 4096] {
        let mut x: Vec<f32> = (0..m).map(|i| i as f32).collect();
        set.run(&format!("fwht m={m}"), move || {
            fwht_inplace(black_box(&mut x));
        });
    }

    // BWHT layer-scale transform.
    let b = Bwht::for_dim(960, 512);
    let x: Vec<f32> = (0..960).map(|i| (i as f32).sin()).collect();
    set.run("bwht 960ch (MobileNetV2 head dim)", move || {
        black_box(b.forward(&x));
    });

    // Crossbar bitplane op (the analog inner loop).
    let mut rng = Rng::new(1);
    for m in [32usize, 128] {
        let mut xb = Crossbar::walsh(m, CrossbarConfig::default(), &mut rng);
        let x = BitVec::from_bits(&(0..m).map(|i| i % 2 == 0).collect::<Vec<_>>());
        let mut r = Rng::new(2);
        set.run(&format!("crossbar {m}x{m} bitplane"), move || {
            black_box(xb.process_bitplane(&x, &mut r));
        });
    }

    // Multi-bit engine transform (4 planes).
    let mut eng = BitplaneEngine::new(
        Crossbar::walsh(32, CrossbarConfig::default(), &mut Rng::new(3)),
        4,
    );
    let xq: Vec<u32> = (0..32).map(|i| (i as u32 * 3) % 16).collect();
    let mut r = Rng::new(4);
    set.run("bitplane engine 32ch 4-bit", move || {
        black_box(eng.transform(&xq, &mut r));
    });

    // Full model forward (analog BWHT digit MLP, float mode).
    let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(5));
    let img = Tensor::vec1(&vec![0.5f32; 144]);
    set.run("digit MLP forward (float)", move || {
        black_box(model.forward(&img));
    });
}
