//! Bench: Fig 3 — crossbar timing diagram + single-op simulation cost.

use adcim::cim::{BitVec, Crossbar, CrossbarConfig};
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig3::generate());

    let mut set = BenchSet::new("crossbar op simulation cost");
    let mut rng = Rng::new(1);
    for m in [16usize, 32, 64, 128] {
        let mut xb = Crossbar::walsh(m, CrossbarConfig::default(), &mut rng);
        let bits: Vec<bool> = (0..m).map(|i| i % 3 == 0).collect();
        let x = BitVec::from_bits(&bits);
        let mut r = Rng::new(2);
        set.run(&format!("{m}x{m} four-step op"), move || {
            black_box(xb.process_bitplane(&x, &mut r));
        });
    }
}
