//! Bench: Fig 7 — VDD / size / clock sweeps + raw crossbar hot-loop rate.

use adcim::cim::{BitVec, Crossbar, CrossbarConfig};
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig7::generate());

    let mut set = BenchSet::new("crossbar hot loop (cell-ops/s derived)");
    let mut rng = Rng::new(1);
    let m = 128usize;
    let mut xb = Crossbar::walsh(m, CrossbarConfig::default(), &mut rng);
    let x = BitVec::from_bits(&(0..m).map(|i| i % 2 == 0).collect::<Vec<_>>());
    let mut r = Rng::new(2);
    let meas = set.run("128x128 bitplane op", move || {
        black_box(xb.process_bitplane(&x, &mut r));
    });
    let cell_ops = (m * m) as f64 * meas.per_sec();
    println!("≈ {cell_ops:.2e} cell-ops/s/core");
}
