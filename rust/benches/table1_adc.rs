//! Bench: Table I — ADC comparison. Prints the paper's table (model
//! anchors) and times conversions per style on the behavioural path.

use adcim::adc::{Adc, FlashAdc, ImmersedAdc, ImmersedMode, SarAdc};
use adcim::analog::NoiseModel;
use adcim::util::bench::BenchSet;
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::table1::generate());

    let mut set = BenchSet::new("conversion throughput (behavioural, 5-bit)");
    let noise = NoiseModel::default();
    let mut rng = Rng::new(1);
    let mut sar = SarAdc::sample(5, 1.0, &noise, &mut rng);
    let mut flash = FlashAdc::sample(5, 1.0, &noise, &mut rng);
    let mut imm = ImmersedAdc::sample(5, 1.0, ImmersedMode::Sar, 32, 20.0, &noise, &mut rng);
    let hybrid = ImmersedMode::Hybrid { flash_bits: 2 };
    let mut hyb = ImmersedAdc::sample(5, 1.0, hybrid, 32, 20.0, &noise, &mut rng);
    let mut v = 0.0f64;
    let mut tick = move || {
        v = (v + 0.137).fract();
        v
    };
    set.run("conventional SAR", {
        let mut t = tick.clone();
        move || {
            let _ = std::hint::black_box(sar.convert(t(), &mut Rng::new(2)));
        }
    });
    set.run("conventional Flash", {
        let mut t = tick.clone();
        move || {
            let _ = std::hint::black_box(flash.convert(t(), &mut Rng::new(3)));
        }
    });
    set.run("immersed SAR", {
        let mut t = tick.clone();
        move || {
            let _ = std::hint::black_box(imm.convert(t(), &mut Rng::new(4)));
        }
    });
    set.run("immersed hybrid", move || {
        let _ = std::hint::black_box(hyb.convert(tick(), &mut Rng::new(5)));
    });
}
