//! Bench: Fig 6 — early termination report + workload saving measured
//! as actual simulation speedup.

use adcim::cim::{BitplaneEngine, Crossbar, CrossbarConfig, EarlyTermination};
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig6::generate());

    let mut set = BenchSet::new("bitplane transform with/without termination");
    let m = 32usize;
    let bits = 6u8;
    let x: Vec<u32> = (0..m).map(|i| ((i * 5) % (1 << bits)) as u32).collect();
    for (name, et) in [
        ("no termination", None),
        ("exact T=32", Some(EarlyTermination::exact(32.0))),
        ("aggressive T=32 x2", Some(EarlyTermination::aggressive(32.0, 2.0))),
    ] {
        let mut eng = BitplaneEngine::new(
            Crossbar::walsh(m, CrossbarConfig::default(), &mut Rng::new(1)),
            bits,
        );
        eng.early_term = et;
        let x = x.clone();
        let mut r = Rng::new(2);
        set.run(name, move || {
            black_box(eng.transform(&x, &mut r));
        });
    }
}
