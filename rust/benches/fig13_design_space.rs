//! Bench: Fig 13 — design space + the accuracy-sweep workhorse cost.

use adcim::cim::CrossbarConfig;
use adcim::report::support::{analog_accuracy, trained_digit_mlp};
use adcim::util::bench::{black_box, BenchSet};

fn main() {
    println!("{}", adcim::report::fig13::generate());

    let mut set = BenchSet::new("one analog accuracy evaluation (80 test images)");
    let (mut model, te, _acc) = trained_digit_mlp(13, 2, 0.0);
    set.run("analog eval @ nominal", move || {
        black_box(analog_accuracy(&mut model, &te, CrossbarConfig::default(), 4, None, 5));
    });
}
