//! Bench: Fig 1(c) — compression/accuracy vs WHT layers, plus timing of
//! the miniature training epoch the sweep rests on.

use adcim::nn::model::mini_resnet;
use adcim::nn::train::{train, TrainConfig};
use adcim::nn::Dataset;
use adcim::util::bench::BenchSet;
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig1::fig1c());

    let mut set = BenchSet::new("training cost (miniature ResNet, 1 epoch)");
    // CHW frames — the conv model takes unflattened images.
    let (tr, te) = Dataset::digits(120, 12, 1).split(0.8);
    for bwht in [0usize, 2] {
        set.run(&format!("{bwht} BWHT stages"), || {
            let mut rng = Rng::new(9);
            let mut m = mini_resnet(12, 10, 8, 2, bwht, &mut rng);
            let _ = train(&mut m, &tr, &te, TrainConfig { epochs: 1, ..Default::default() });
        });
    }
}
