//! Bench: Fig 10 — MAV stats + asymmetric search; tree build & convert cost.

use adcim::adc::{binomial_mav_pmf, AsymmetricSearch, ImmersedAdc, ImmersedMode};
use adcim::util::bench::{black_box, BenchSet};
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig10::generate());

    let mut set = BenchSet::new("asymmetric search costs");
    let pmf = binomial_mav_pmf(32, 0.5, 5);
    set.run("optimal tree build (5-bit)", || {
        black_box(AsymmetricSearch::build(5, &pmf));
    });
    let tree = AsymmetricSearch::build(5, &pmf);
    let mut adc = ImmersedAdc::ideal(5, 1.0, ImmersedMode::Sar);
    let mut r = Rng::new(3);
    let mut v = 0.0f64;
    set.run("asymmetric conversion", move || {
        v = (v + 0.231).fract();
        black_box(tree.convert(&mut adc, v, &mut r));
    });
}
