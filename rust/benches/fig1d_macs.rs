//! Bench: Fig 1(d) — MAC accounting (analytic) + table construction cost.

use adcim::nn::macs::{compression_summary, mobilenet_v2_table, resnet20_table};
use adcim::util::bench::{black_box, BenchSet};

fn main() {
    println!("{}", adcim::report::fig1::fig1d());

    let mut set = BenchSet::new("accounting cost");
    set.run("mobilenet_v2 table + summary", || {
        let t = mobilenet_v2_table();
        black_box(compression_summary(&t));
    });
    set.run("resnet20 table + summary", || {
        let t = resnet20_table();
        black_box(compression_summary(&t));
    });
}
