//! Bench: Fig 5 — quantization-aware training sweep + per-epoch cost of
//! the 1-bit product-sum forward.

use adcim::nn::bwht_layer::BwhtExec;
use adcim::nn::model::bwht_mlp;
use adcim::nn::train::{train, TrainConfig};
use adcim::report::support::digit_data;
use adcim::util::bench::BenchSet;
use adcim::util::Rng;

fn main() {
    println!("{}", adcim::report::fig5::generate());

    let mut set = BenchSet::new("1 training epoch (digit MLP)");
    let (tr, te) = digit_data(120, 3);
    set.run("float forward", || {
        let mut m = bwht_mlp(144, 10, 32, &mut Rng::new(1));
        let _ = train(&mut m, &tr, &te, TrainConfig { epochs: 1, ..Default::default() });
    });
    set.run("1-bit product-sum forward (4-bit input)", || {
        let mut m = bwht_mlp(144, 10, 32, &mut Rng::new(1));
        m.for_each_bwht(|b| b.set_exec(BwhtExec::QuantDigital { input_bits: 4 }));
        let _ = train(&mut m, &tr, &te, TrainConfig { epochs: 1, ..Default::default() });
    });
}
