//! ADC characterization bench-top: everything the paper measured on the
//! 65 nm chip, against the behavioural converter — staircase, DNL/INL,
//! per-mode latency/energy, asymmetric-search savings, and the Table I
//! comparison. A compact tour of the `adc` + `energy` modules.
//!
//! Run: `cargo run --release --example adc_characterization`

use adcim::adc::metrics::{linearity, staircase};
use adcim::adc::{binomial_mav_pmf, Adc, AsymmetricSearch, ImmersedAdc, ImmersedMode};
use adcim::analog::NoiseModel;
use adcim::energy::{adc_area_um2, adc_energy_pj, adc_latency_cycles, AdcStyle};
use adcim::util::Rng;

fn main() {
    let bits = 5u8;
    let mut rng = Rng::new(0xcafe);
    let noise = NoiseModel::default();

    println!("== memory-immersed converter, {bits}-bit, paper geometry (16x32 arrays) ==\n");
    for mode in [ImmersedMode::Sar, ImmersedMode::Flash, ImmersedMode::Hybrid { flash_bits: 2 }] {
        let mut adc = ImmersedAdc::sample(bits, 1.0, mode, 32, 20.0, &noise, &mut rng);
        let lin = linearity(&mut adc, 32, &mut rng);
        let c = adc.convert(0.6180, &mut rng);
        println!(
            "{:<26} cycles {:>2}  comparisons {:>2}  energy {:>7.1} fJ  |DNL|max {:.3}  \
             |INL|max {:.3}",
            format!("{mode:?}"),
            c.cycles,
            c.comparisons,
            c.energy_fj,
            lin.max_abs_dnl(),
            lin.max_abs_inl()
        );
    }

    // Staircase sample (Fig 12a).
    println!("\nstaircase (every 16th point):");
    let mut adc = ImmersedAdc::sample(
        bits,
        1.0,
        ImmersedMode::Hybrid { flash_bits: 2 },
        32,
        20.0,
        &noise,
        &mut rng,
    );
    for (v, code) in staircase(&mut adc, 128, &mut rng).iter().step_by(16) {
        let stars = "#".repeat(*code as usize / 2);
        println!("  {v:.3} V  {code:>3}  {stars}");
    }

    // Asymmetric search (Fig 10).
    let pmf = binomial_mav_pmf(32, 0.5, bits);
    let tree = AsymmetricSearch::build(bits, &pmf);
    println!(
        "\nasymmetric search: E[comparisons] {:.2} vs 5 symmetric ({}% fewer)",
        tree.expected_comparisons(),
        ((1.0 - tree.expected_comparisons() / 5.0) * 100.0).round()
    );

    // Table I shape.
    println!("\n== Table I reproduction (5-bit, 10 MHz) ==");
    println!("{:<30} {:>12} {:>12} {:>8}", "style", "area µm²", "energy pJ", "cycles");
    for s in AdcStyle::ALL {
        println!(
            "{:<30} {:>12.2} {:>12.2} {:>8}",
            s.name(),
            adc_area_um2(s, bits),
            adc_energy_pj(s, bits),
            adc_latency_cycles(s, bits)
        );
    }
    println!("\nadc_characterization OK");
}
