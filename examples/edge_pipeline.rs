//! End-to-end edge-serving driver — the E2E validation run recorded in
//! EXPERIMENTS.md: a stream of synthetic sensor frames flows through the
//! full stack (router → dynamic batcher → worker pool), once on the
//! **digital reference** engine (the AOT-compiled JAX/Pallas model on
//! PJRT) and once on the **analog CiM pool** (the paper's crossbar +
//! collaborative-ADC simulator with the same trained weights), proving
//! all three layers compose. Reports accuracy, latency and throughput.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example edge_pipeline

use std::time::{Duration, Instant};

use adcim::cim::{CrossbarConfig, EarlyTermination};
use adcim::config::ServerConfig;
use adcim::coordinator::{
    AnalogEngine, DigitalEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::nn::Dataset;
use adcim::runtime::Artifacts;

const FRAMES: usize = 512;

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::open(Artifacts::default_dir())?;
    let manifest = artifacts.manifest()?;
    println!(
        "artifacts: batch {}, input {}, classes {} (trained by python/compile/aot.py)",
        manifest.batch, manifest.input, manifest.classes
    );
    let data = Dataset::digits(FRAMES, 12, 0xed6e);

    // ---- digital reference path (PJRT) -------------------------------
    let digital: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|_| Box::new(DigitalEngine::load(&artifacts, false).unwrap()) as Box<_>)
        .collect();
    run_load("digital (PJRT, AOT JAX/Pallas)", digital, &data, &manifest)?;

    // ---- analog CiM pool (same weights, simulated hardware) ----------
    let analog: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|w| {
            Box::new(
                AnalogEngine::load(
                    &artifacts,
                    CrossbarConfig::default(),
                    Some(EarlyTermination::exact(6.0)),
                    manifest.input_bits,
                    w as u64,
                )
                .unwrap(),
            ) as Box<_>
        })
        .collect();
    run_load("analog (CiM crossbar pool)", analog, &data, &manifest)?;

    Ok(())
}

fn run_load(
    label: &str,
    engines: Vec<Box<dyn InferenceEngine>>,
    data: &Dataset,
    manifest: &adcim::runtime::Manifest,
) -> anyhow::Result<()> {
    println!("\n== {label} ==");
    let cfg = ServerConfig {
        workers: engines.len(),
        batch: manifest.batch,
        batch_deadline_us: 2000,
        queue_depth: 4096,
        engine: String::new(),
    };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::LeastLoaded)?;

    let t0 = Instant::now();
    let mut submitted = 0u64;
    for (i, img) in data.images.iter().enumerate() {
        let flat = img.clone().reshape(&[manifest.input]);
        if server.submit(InferenceRequest::new(i as u64, (i % 8) as u32, flat.data().to_vec())) {
            submitted += 1;
        }
    }
    let mut correct = 0usize;
    let mut got = 0u64;
    while got < submitted {
        match server.recv_response(Duration::from_secs(30)) {
            Some(r) => {
                if r.class == data.labels[r.id as usize] {
                    correct += 1;
                }
                got += 1;
            }
            None => break,
        }
    }
    let wall = t0.elapsed();
    let shed = server.shed_count();
    let snap = server.shutdown();
    println!("   {snap}");
    println!(
        "   served {got}/{submitted} frames in {:.2}s  ({:.0} frames/s wall), shed {shed}",
        wall.as_secs_f64(),
        got as f64 / wall.as_secs_f64()
    );
    println!("   accuracy {:.3} ({correct}/{got})", correct as f64 / got.max(1) as f64);
    anyhow::ensure!(got == submitted, "lost responses: {got}/{submitted}");
    Ok(())
}
