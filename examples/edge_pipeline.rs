//! End-to-end edge-serving driver — the E2E validation run recorded in
//! EXPERIMENTS.md: a stream of synthetic sensor frames flows through the
//! full stack (router → dynamic batcher → worker pool), once on the
//! **digital reference** engine (the AOT-compiled JAX/Pallas model on
//! PJRT), once on the **analog CiM engine** with the ADC-free 1-bit
//! path, and once through the **collaborative digitization pool** — the
//! Fig 11 fabricated-chip shape: four 16×32 arrays taking turns
//! computing MAVs and digitizing their neighbour's through
//! memory-immersed converters. Reports accuracy, latency, throughput
//! and the pool's per-conversion metrics (comparisons/conversion,
//! cycles, fJ per request).
//!
//! NOTE: this file is an illustrative driver, not a registered cargo
//! example target (it lives at the repo root, outside the `rust/`
//! package, because the digital section needs the off-by-default `xla`
//! feature plus `make artifacts`). To run it, copy into
//! `rust/examples/` on a machine with PJRT and build with
//! `--features xla`; the analog and pooled sections also run without
//! `xla` if the digital block is removed. The same pooled serving path
//! is driven artifact-free by `rust/tests/pool_serving.rs` and by
//! `adcim serve --engine analog --pool 4`.

use std::time::{Duration, Instant};

use adcim::adc::ImmersedMode;
use adcim::cim::{CrossbarConfig, EarlyTermination, PoolSpec};
use adcim::config::ServerConfig;
#[cfg(feature = "xla")]
use adcim::coordinator::DigitalEngine;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::nn::Dataset;
use adcim::runtime::Artifacts;

const FRAMES: usize = 512;

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::open(Artifacts::default_dir())?;
    let manifest = artifacts.manifest()?;
    println!(
        "artifacts: batch {}, input {}, classes {} (trained by python/compile/aot.py)",
        manifest.batch, manifest.input, manifest.classes
    );
    let data = Dataset::digits(FRAMES, 12, 0xed6e);

    // ---- digital reference path (PJRT; xla builds only) --------------
    #[cfg(feature = "xla")]
    {
        let digital: Vec<Box<dyn InferenceEngine>> = (0..2)
            .map(|_| Box::new(DigitalEngine::load(&artifacts, false).unwrap()) as Box<_>)
            .collect();
        run_load("digital (PJRT, AOT JAX/Pallas)", digital, &data, &manifest)?;
    }

    // ---- analog CiM, ADC-free 1-bit default path ---------------------
    let analog: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|w| {
            Box::new(
                AnalogEngine::load(
                    &artifacts,
                    CrossbarConfig::default(),
                    Some(EarlyTermination::exact(6.0)),
                    manifest.input_bits,
                    w as u64,
                )
                .unwrap(),
            ) as Box<_>
        })
        .collect();
    run_load("analog (CiM crossbar, 1-bit ADC-free)", analog, &data, &manifest)?;

    // ---- analog CiM through the 4-array collaborative pool -----------
    // The Fig 11 fabricated-chip shape: nearest-neighbour SAR coupling,
    // 5-bit memory-immersed conversion, MAVs digitized exactly once per
    // phase by the partner array.
    let spec = PoolSpec::fig11(ImmersedMode::Sar);
    let pooled: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|w| {
            Box::new(
                AnalogEngine::load(
                    &artifacts,
                    CrossbarConfig::default(),
                    None,
                    manifest.input_bits,
                    w as u64,
                )
                .unwrap()
                .with_pool(Some(spec))
                .unwrap(),
            ) as Box<_>
        })
        .collect();
    run_load("analog (4-array collaborative digitization pool)", pooled, &data, &manifest)?;

    Ok(())
}

fn run_load(
    label: &str,
    engines: Vec<Box<dyn InferenceEngine>>,
    data: &Dataset,
    manifest: &adcim::runtime::Manifest,
) -> anyhow::Result<()> {
    println!("\n== {label} ==");
    let cfg = ServerConfig {
        workers: engines.len(),
        batch: manifest.batch,
        batch_deadline_us: 2000,
        queue_depth: 4096,
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::LeastLoaded)?;

    let t0 = Instant::now();
    let mut submitted = 0u64;
    for (i, img) in data.images.iter().enumerate() {
        let flat = img.clone().reshape(&[manifest.input]);
        if server.submit(InferenceRequest::new(i as u64, (i % 8) as u32, flat.data().to_vec())) {
            submitted += 1;
        }
    }
    let mut correct = 0usize;
    let mut got = 0u64;
    while got < submitted {
        match server.recv_response(Duration::from_secs(30)) {
            Some(r) => {
                if r.class == data.labels[r.id as usize] {
                    correct += 1;
                }
                got += 1;
            }
            None => break,
        }
    }
    let wall = t0.elapsed();
    let shed = server.shed_count();
    let snap = server.shutdown();
    println!("   {snap}");
    println!(
        "   served {got}/{submitted} frames in {:.2}s  ({:.0} frames/s wall), shed {shed}",
        wall.as_secs_f64(),
        got as f64 / wall.as_secs_f64()
    );
    println!("   accuracy {:.3} ({correct}/{got})", correct as f64 / got.max(1) as f64);
    if snap.conversions > 0 {
        println!(
            "   pool: {} conversions, {:.2} comparisons/conv, {} cycles, {:.1} fJ/request",
            snap.conversions,
            snap.comparisons_per_conversion,
            snap.adc_cycles,
            snap.energy_per_req_fj
        );
    }
    anyhow::ensure!(got == submitted, "lost responses: {got}/{submitted}");
    Ok(())
}
