//! End-to-end edge-serving driver — the E2E validation run recorded in
//! EXPERIMENTS.md: a stream of synthetic sensor frames flows through the
//! full stack (router → dynamic batcher → worker pool), once on the
//! **digital reference** engine (the AOT-compiled JAX/Pallas model on
//! PJRT), once on the **analog CiM engine** with the ADC-free 1-bit
//! path, and once through the **collaborative digitization pool** — the
//! Fig 11 fabricated-chip shape: four 16×32 arrays taking turns
//! computing MAVs and digitizing their neighbour's through
//! memory-immersed converters — and finally through the **frequency-
//! domain sensor frontend**: the same deluge (padded with blank filler
//! frames) is sequency-compressed, triaged keep/summarize/drop, and the
//! survivors served as native compressed payloads. Reports accuracy,
//! latency, throughput, the pool's per-conversion metrics and the
//! frontend's byte-reduction counters.
//!
//! NOTE: this is a registered cargo example (rust/Cargo.toml
//! `[[example]]`, path `../examples/edge_pipeline.rs`), so tier-1 CI
//! compiles it; *running* it needs `make artifacts`, and the digital
//! section additionally needs a build with `--features xla`. The same
//! serving paths are driven artifact-free by
//! `rust/tests/pool_serving.rs`, `rust/tests/frontend_serving.rs`, and
//! `adcim serve --engine analog --pool 4 --frontend`.

use std::time::{Duration, Instant};

use adcim::adc::ImmersedMode;
use adcim::cim::{CrossbarConfig, EarlyTermination, PoolSpec};
use adcim::config::ServerConfig;
#[cfg(feature = "xla")]
use adcim::coordinator::DigitalEngine;
use adcim::coordinator::{
    AnalogEngine, EdgeServer, InferenceEngine, InferenceRequest, RoutingPolicy,
};
use adcim::frontend::{
    CodecParams, FrontendConfig, IngestDecision, RetentionPolicy, Selection, SensorFrontend,
};
use adcim::nn::Dataset;
use adcim::runtime::Artifacts;
use adcim::util::Rng;

const FRAMES: usize = 512;

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::open(Artifacts::default_dir())?;
    let manifest = artifacts.manifest()?;
    println!(
        "artifacts: batch {}, input {}, classes {} (trained by python/compile/aot.py)",
        manifest.batch, manifest.input, manifest.classes
    );
    let data = Dataset::digits(FRAMES, 12, 0xed6e);

    // ---- digital reference path (PJRT; xla builds only) --------------
    #[cfg(feature = "xla")]
    {
        let digital: Vec<Box<dyn InferenceEngine>> = (0..2)
            .map(|_| Box::new(DigitalEngine::load(&artifacts, false).unwrap()) as Box<_>)
            .collect();
        run_load("digital (PJRT, AOT JAX/Pallas)", digital, &data, &manifest)?;
    }

    // ---- analog CiM, ADC-free 1-bit default path ---------------------
    let analog: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|w| {
            Box::new(
                AnalogEngine::load(
                    &artifacts,
                    CrossbarConfig::default(),
                    Some(EarlyTermination::exact(6.0)),
                    manifest.input_bits,
                    w as u64,
                )
                .unwrap(),
            ) as Box<_>
        })
        .collect();
    run_load("analog (CiM crossbar, 1-bit ADC-free)", analog, &data, &manifest)?;

    // ---- analog CiM through the 4-array collaborative pool -----------
    // The Fig 11 fabricated-chip shape: nearest-neighbour SAR coupling,
    // 5-bit memory-immersed conversion, MAVs digitized exactly once per
    // phase by the partner array.
    let spec = PoolSpec::fig11(ImmersedMode::Sar);
    let pooled: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|w| {
            Box::new(
                AnalogEngine::load(
                    &artifacts,
                    CrossbarConfig::default(),
                    None,
                    manifest.input_bits,
                    w as u64,
                )
                .unwrap()
                .with_pool(Some(spec))
                .unwrap(),
            ) as Box<_>
        })
        .collect();
    run_load("analog (4-array collaborative digitization pool)", pooled, &data, &manifest)?;

    // ---- the deluge through the sensor frontend ----------------------
    // Same digit frames plus 50% blank filler; the frontend compresses
    // each to its top-32 sequency coefficients at 8 bits, triages, and
    // only the survivors reach the queue — as compressed payloads.
    let fe_engines: Vec<Box<dyn InferenceEngine>> = (0..2)
        .map(|w| {
            Box::new(
                AnalogEngine::load(
                    &artifacts,
                    CrossbarConfig::default(),
                    None,
                    manifest.input_bits,
                    w as u64,
                )
                .unwrap(),
            ) as Box<_>
        })
        .collect();
    run_frontend_load(fe_engines, &data, &manifest)?;

    Ok(())
}

/// Fourth stage: serve a mixed deluge through the frequency-domain
/// frontend and print `FrontendStats` next to the serving metrics.
fn run_frontend_load(
    engines: Vec<Box<dyn InferenceEngine>>,
    data: &Dataset,
    manifest: &adcim::runtime::Manifest,
) -> anyhow::Result<()> {
    println!("\n== analog + frequency-domain sensor frontend ==");
    let cfg = ServerConfig {
        workers: engines.len(),
        batch: manifest.batch,
        batch_deadline_us: 2000,
        queue_depth: 4096,
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::LeastLoaded)?;
    let params = CodecParams::new(1, manifest.input, 8, 8)
        .map_err(|e| anyhow::anyhow!("codec: {e}"))?;
    let mut frontend = SensorFrontend::new(FrontendConfig {
        policy: RetentionPolicy::triage_default(),
        ..FrontendConfig::new(params, Selection::TopK(32))
    });

    let mut rng = Rng::new(0xb1a);
    let mut submitted = 0u64;
    let mut offered = 0u64;
    for (i, img) in data.images.iter().enumerate() {
        let flat = img.clone().reshape(&[manifest.input]);
        // Real frame, then one blank filler frame (ids interleave 2:1).
        for (slot, frame) in [
            flat.data().to_vec(),
            (0..manifest.input).map(|_| (0.5 + 0.01 * rng.normal()) as f32).collect(),
        ]
        .into_iter()
        .enumerate()
        {
            let id = 2 * i as u64 + slot as u64;
            offered += 1;
            if let IngestDecision::Keep(cf) = frontend.ingest(&frame, id, (i % 8) as u32) {
                if server.submit(InferenceRequest::compressed(id, (i % 8) as u32, cf)).is_ok() {
                    submitted += 1;
                }
            }
        }
    }
    let mut correct = 0usize;
    let mut digits = 0u64;
    let mut got = 0u64;
    while got < submitted {
        match server.recv_response(Duration::from_secs(30)) {
            Some(r) => {
                // Even ids are real digit frames; blanks have no label.
                if r.id % 2 == 0 {
                    digits += 1;
                    if r.class == data.labels[(r.id / 2) as usize] {
                        correct += 1;
                    }
                }
                got += 1;
            }
            None => break,
        }
    }
    server.record_frontend(&frontend.take_stats());
    let snap = server.shutdown();
    println!("   {snap}");
    println!(
        "   deluge: {offered} frames offered, {submitted} served compressed, \
         accuracy on kept digits {:.3} ({correct}/{digits})",
        correct as f64 / digits.max(1) as f64
    );
    println!(
        "   ingest bytes {} -> {} ({:.1}x reduction)",
        snap.frontend.bytes_in,
        snap.frontend.bytes_out,
        snap.frontend.compression_ratio()
    );
    anyhow::ensure!(got == submitted, "lost responses: {got}/{submitted}");
    Ok(())
}

fn run_load(
    label: &str,
    engines: Vec<Box<dyn InferenceEngine>>,
    data: &Dataset,
    manifest: &adcim::runtime::Manifest,
) -> anyhow::Result<()> {
    println!("\n== {label} ==");
    let cfg = ServerConfig {
        workers: engines.len(),
        batch: manifest.batch,
        batch_deadline_us: 2000,
        queue_depth: 4096,
        ..Default::default()
    };
    let server = EdgeServer::start(&cfg, engines, RoutingPolicy::LeastLoaded)?;

    let t0 = Instant::now();
    let mut submitted = 0u64;
    for (i, img) in data.images.iter().enumerate() {
        let flat = img.clone().reshape(&[manifest.input]);
        if server
            .submit(InferenceRequest::new(i as u64, (i % 8) as u32, flat.data().to_vec()))
            .is_ok()
        {
            submitted += 1;
        }
    }
    let mut correct = 0usize;
    let mut got = 0u64;
    while got < submitted {
        match server.recv_response(Duration::from_secs(30)) {
            Some(r) => {
                if r.class == data.labels[r.id as usize] {
                    correct += 1;
                }
                got += 1;
            }
            None => break,
        }
    }
    let wall = t0.elapsed();
    let shed = server.shed_count();
    let snap = server.shutdown();
    println!("   {snap}");
    println!(
        "   served {got}/{submitted} frames in {:.2}s  ({:.0} frames/s wall), shed {shed}",
        wall.as_secs_f64(),
        got as f64 / wall.as_secs_f64()
    );
    println!("   accuracy {:.3} ({correct}/{got})", correct as f64 / got.max(1) as f64);
    if snap.conversions > 0 {
        println!(
            "   pool: {} conversions, {:.2} comparisons/conv, {} cycles, {:.1} fJ/request",
            snap.conversions,
            snap.comparisons_per_conversion,
            snap.adc_cycles,
            snap.energy_per_req_fj
        );
    }
    anyhow::ensure!(got == submitted, "lost responses: {got}/{submitted}");
    Ok(())
}
