//! Quickstart: the library in five minutes.
//!
//! 1. Build a Walsh–Hadamard transform and run it through the analog
//!    crossbar simulator.
//! 2. Digitize a crossbar MAV with the memory-immersed collaborative
//!    ADC (SAR / hybrid / asymmetric-search modes).
//! 3. Train a tiny frequency-domain digit classifier and evaluate it on
//!    the simulated hardware at two operating points.
//!
//! Run: `cargo run --release --example quickstart`

use adcim::adc::{binomial_mav_pmf, Adc, AsymmetricSearch, ImmersedAdc, ImmersedMode};
use adcim::analog::OperatingPoint;
use adcim::cim::{BitplaneEngine, BitVec, Crossbar, CrossbarConfig};
use adcim::nn::model::bwht_mlp;
use adcim::nn::train::{train, TrainConfig};
use adcim::nn::Dataset;
use adcim::util::Rng;
use adcim::wht::fwht_inplace;

fn main() {
    let mut rng = Rng::new(42);

    // --- 1. The transform, digitally and in analog -------------------
    println!("== 1. Walsh–Hadamard transform on the analog crossbar ==");
    let m = 32;
    let x: Vec<u32> = (0..m).map(|i| (i as u32 * 7) % 16).collect();
    // Digital reference: FWHT of the integer vector.
    let mut reference: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    fwht_inplace(&mut reference);

    // Analog: 4 input bitplanes through the simulated crossbar with
    // 1-bit product-sum quantization (the paper's ADC-free scheme).
    let crossbar = Crossbar::walsh(m, CrossbarConfig::default(), &mut rng);
    let mut engine = BitplaneEngine::new(crossbar, 4);
    let out = engine.transform(&x, &mut rng);
    let corr = correlation(&out.values, &reference);
    println!("   1-bit-quantized analog output correlates {corr:.3} with exact transform");
    println!("   (training absorbs the rest — see step 3)");

    // --- 2. Memory-immersed digitization ------------------------------
    println!("\n== 2. Collaborative digitization of a crossbar MAV ==");
    let bits = 5;
    let plane = BitVec::from_bits(&(0..m).map(|i| i % 3 == 0).collect::<Vec<_>>());
    let mav = engine.crossbar_mut().compute_mav(&plane, &mut rng)[0];
    for mode in [ImmersedMode::Sar, ImmersedMode::Hybrid { flash_bits: 2 }] {
        let mut adc = ImmersedAdc::ideal(bits, 1.0, mode);
        let c = adc.convert(mav, &mut rng);
        println!(
            "   {mode:?}: MAV {mav:.3} V -> code {} in {} cycles ({} comparisons)",
            c.code, c.cycles, c.comparisons
        );
    }
    let tree = AsymmetricSearch::build(bits, &binomial_mav_pmf(m, 0.5, bits));
    let mut adc = ImmersedAdc::ideal(bits, 1.0, ImmersedMode::Sar);
    let c = tree.convert(&mut adc, mav, &mut rng);
    println!(
        "   asymmetric search: code {} in {} comparisons (expected {:.2} vs 5 symmetric)",
        c.code,
        c.comparisons,
        tree.expected_comparisons()
    );

    // --- 3. A frequency-domain classifier on simulated hardware -------
    println!("\n== 3. BWHT digit classifier: float vs analog inference ==");
    let data = Dataset::digits(300, 12, 7);
    let flat = |d: Dataset| Dataset {
        images: d.images.into_iter().map(|i| i.reshape(&[144])).collect(),
        labels: d.labels,
        classes: d.classes,
        side: d.side,
    };
    let (tr, te) = data.split(0.8);
    let (tr, te) = (flat(tr), flat(te));
    let mut model = bwht_mlp(144, 10, 32, &mut Rng::new(1));
    let log = train(&mut model, &tr, &te, TrainConfig { epochs: 4, ..Default::default() });
    println!("   float test accuracy: {:.3}", log.epoch_test_acc.last().unwrap());

    for (label, op) in [
        ("nominal 1.0 V / 1 GHz", OperatingPoint::new(1.0, 1.0)),
        ("starved 0.55 V / 4 GHz", OperatingPoint::new(0.55, 4.0)),
    ] {
        let cfg = CrossbarConfig { op, ..Default::default() };
        let acc = adcim::report::support::analog_accuracy(&mut model, &te, cfg, 4, None, 9);
        println!("   analog @ {label}: accuracy {acc:.3}");
    }
    println!("\nquickstart OK");
}

fn correlation(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
    let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    dot / (na * nb + 1e-12)
}
