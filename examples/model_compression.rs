//! Frequency-domain model compression walkthrough (paper §II, Fig 1):
//! the analytic full-dimension accounting for MobileNetV2 and ResNet20,
//! plus a live miniature: train a model, swap 1×1 mixers for BWHT
//! layers, and watch parameters collapse while accuracy holds.
//!
//! Run: `cargo run --release --example model_compression`

use adcim::nn::macs::{compression_summary, mobilenet_v2_table, resnet20_table};
use adcim::nn::model::mini_resnet;
use adcim::nn::train::{train, TrainConfig};
use adcim::nn::Dataset;
use adcim::util::Rng;

fn main() {
    // ---- analytic, at the paper's published dimensions ---------------
    println!("== full-dimension accounting (no training required) ==\n");
    for (name, table) in
        [("MobileNetV2 @224²", mobilenet_v2_table()), ("ResNet20 @32²", resnet20_table())]
    {
        let s = compression_summary(&table);
        println!("{name}:");
        println!(
            "  params: {:>9} -> {:>9}  ({:.1}% total reduction, {:.1}% of features)",
            s.params_base,
            s.params_bwht,
            s.reduction_total * 100.0,
            s.reduction_features * 100.0
        );
        println!(
            "  MACs:   {:>9} -> {:>9} dense-crossbar ops ({:.2}x — why the paper builds \
             the analog accelerator)",
            s.macs_base, s.macs_bwht_dense, s.mac_increase_dense
        );
        println!();
    }

    // ---- live miniature ----------------------------------------------
    println!("== miniature ResNet on the digit workload ==\n");
    let data = Dataset::digits(300, 12, 77);
    let (tr, te) = data.split(0.8);
    println!("{:>12} {:>10} {:>10}", "BWHT stages", "params", "test acc");
    for bwht_stages in 0..=2usize {
        let mut rng = Rng::new(5);
        let mut model = mini_resnet(12, 10, 8, 2, bwht_stages, &mut rng);
        let log = train(
            &mut model,
            &tr,
            &te,
            TrainConfig { epochs: 3, lr: 0.05, seed: 3, ..Default::default() },
        );
        println!(
            "{bwht_stages:>12} {:>10} {:>10.3}",
            model.param_count(),
            log.epoch_test_acc.last().unwrap()
        );
    }
    println!("\nmodel_compression OK");
}
